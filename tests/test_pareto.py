"""One-dispatch Pareto co-design engine tests (PR 10).

Covers the device-resident archive (property: never holds a dominated
point; deterministic capacity eviction; numpy/device agreement), the
scalarization weights and hypervolume metric, the traced-topology twins
(`placement_tables_from_lut_jnp`, `_activation_order_mesh`) pinned
against their static-config originals, the one-dispatch `search_codesign`
engine (engine_stats accounting, determinism, host-oracle re-score
parity), the host engine invariants, and the pre-jit validation messages
for topology grids, knob grids and the islands axis.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import pareto, topology, traffic
from repro.core.constants import NETWORK
from repro.core.gateway_controller import activation_order_jnp
from repro.core.selection import (placement_tables_from_lut_jnp,
                                  placement_tables_jnp)
from repro.core.simulator import (Arch, SimConfig, engine_stats,
                                  rescore_front_host, search_codesign,
                                  search_placement_islands)

MESHES = [(4, 4), (5, 5), (3, 6)]


# ---------------------------------------------------------------------------
# Archive properties
# ---------------------------------------------------------------------------

def _offer_np(batches, capacity, g=2):
    arch = pareto._empty_archive_np(capacity, g)
    for i, obj in enumerate(batches):
        n = len(obj)
        arch = pareto._archive_insert_np(
            arch, obj, np.zeros((n, g, 2), np.int32),
            np.full((n,), i, np.int32), np.arange(n, dtype=np.int32),
            capacity)
    return arch


def _assert_no_dominated(arch):
    obj = np.asarray(arch["obj"])
    valid = np.asarray(arch["valid"])
    rows = obj[valid]
    for i in range(len(rows)):
        for j in range(len(rows)):
            if i == j:
                continue
            dominated = (np.all(rows[j] <= rows[i])
                         and np.any(rows[j] < rows[i]))
            assert not dominated, (
                f"archive row {rows[i]} is dominated by {rows[j]}")


@pytest.mark.parametrize("seed", range(4))
def test_archive_never_holds_dominated_point(seed):
    rng = np.random.RandomState(seed)
    batches = [rng.uniform(0.1, 10.0, size=(rng.randint(1, 9), 3))
               .astype(np.float32) for _ in range(6)]
    for capacity in (4, 16, 64):
        _assert_no_dominated(_offer_np(batches, capacity))


@pytest.mark.parametrize("seed", range(3))
def test_archive_device_matches_numpy_mirror(seed):
    rng = np.random.RandomState(100 + seed)
    capacity, g = 8, 2
    arch_np = pareto._empty_archive_np(capacity, g)
    arch_dev = pareto._empty_archive(capacity, g)
    for i in range(4):
        obj = rng.uniform(0.1, 10.0, size=(5, 3)).astype(np.float32)
        pos = rng.randint(0, 4, size=(5, g, 2)).astype(np.int32)
        tix = np.full((5,), i, np.int32)
        kix = np.arange(5, dtype=np.int32)
        arch_np = pareto._archive_insert_np(arch_np, obj, pos, tix, kix,
                                            capacity)
        arch_dev = pareto._archive_insert(arch_dev, obj, pos, tix, kix,
                                          capacity=capacity)
    for k in ("obj", "pos", "topo", "island", "valid"):
        np.testing.assert_array_equal(np.asarray(arch_dev[k]), arch_np[k],
                                      err_msg=k)


def test_archive_dedup_keeps_earliest():
    obj = np.array([[1.0, 2.0, 3.0]], np.float32)
    arch = pareto._empty_archive_np(8, 2)
    arch = pareto._archive_insert_np(
        arch, obj, np.zeros((1, 2, 2), np.int32),
        np.array([7], np.int32), np.array([0], np.int32), 8)
    arch = pareto._archive_insert_np(
        arch, obj, np.ones((1, 2, 2), np.int32),
        np.array([9], np.int32), np.array([1], np.int32), 8)
    assert int(np.asarray(arch["valid"]).sum()) == 1
    assert int(arch["topo"][np.asarray(arch["valid"])][0]) == 7


def test_archive_capacity_eviction_deterministic():
    # 12 mutually non-dominated points (a 2-D staircase at constant z)
    # with distinct log-sum keys: eviction must keep exactly the capacity
    # best by ascending sum-of-log objectives, independent of insert order.
    n, capacity = 12, 5
    xs = np.arange(1, n + 1, dtype=np.float64)
    ys = 100.0 / xs**1.5                       # distinct products x*y
    pts = np.stack([xs, ys, np.full(n, 2.0)], axis=-1).astype(np.float32)
    key = np.log(np.maximum(pts.astype(np.float64), 1e-12)).sum(axis=1)
    expect = np.sort(key)[:capacity]

    for perm_seed in range(3):
        order = np.random.RandomState(perm_seed).permutation(n)
        arch = _offer_np([pts[order]], capacity)
        valid = np.asarray(arch["valid"])
        assert int(valid.sum()) == capacity
        got = np.sort(np.log(np.asarray(arch["obj"], np.float64)[valid])
                      .sum(axis=1))
        np.testing.assert_allclose(got, expect, rtol=1e-6)


def test_archive_rejects_nonfinite_candidates():
    obj = np.array([[1.0, np.inf, 3.0], [np.nan, 1.0, 1.0]], np.float32)
    arch = _offer_np([obj], 8)
    assert int(np.asarray(arch["valid"]).sum()) == 0


# ---------------------------------------------------------------------------
# Weights + hypervolume
# ---------------------------------------------------------------------------

def test_island_weights_simplex():
    for k in (1, 2, 3, 4, 8, 16):
        w = pareto.island_weights(k)
        assert w.shape == (k, 3)
        assert (w >= 0).all()
        np.testing.assert_allclose(w.sum(axis=1), 1.0, atol=1e-6)
        np.testing.assert_array_equal(w, pareto.island_weights(k))
    np.testing.assert_allclose(pareto.island_weights(1),
                               np.full((1, 3), 1 / 3), atol=1e-6)
    corners = {tuple(r) for r in pareto.island_weights(3).tolist()}
    assert corners == {(1.0, 0.0, 0.0), (0.0, 1.0, 0.0), (0.0, 0.0, 1.0)}
    with pytest.raises(ValueError, match="islands"):
        pareto.island_weights(0)


def test_hypervolume_known_values():
    ref = (2.0, 2.0, 2.0)
    assert pareto.hypervolume(np.empty((0, 3)), ref) == 0.0
    assert pareto.hypervolume([[1.0, 1.0, 1.0]], ref) == pytest.approx(1.0)
    # A dominated point adds nothing; a point outside the box is clipped.
    assert pareto.hypervolume([[1, 1, 1], [1.5, 1.5, 1.5]],
                              ref) == pytest.approx(1.0)
    assert pareto.hypervolume([[1, 1, 1], [3.0, 0.1, 0.1]],
                              ref) == pytest.approx(1.0)
    # Two non-dominated points, inclusion-exclusion: each dominates a
    # 4-volume box, overlapping in a 2-volume one.
    hv = pareto.hypervolume([[0.0, 1.0, 0.0], [1.0, 0.0, 0.0]], ref)
    assert hv == pytest.approx(4 + 4 - 2)


# ---------------------------------------------------------------------------
# Traced-topology twins vs their static-config originals
# ---------------------------------------------------------------------------

def _mesh_cfg(mx, my):
    return dataclasses.replace(NETWORK, mesh_x=mx, mesh_y=my,
                               gateway_positions=None)


def _random_placements(cfg, g, n, seed):
    rng = np.random.RandomState(seed)
    coords = np.asarray(topology.router_coords(cfg))
    return [coords[rng.choice(len(coords), size=g, replace=False)]
            for _ in range(n)]


def test_activation_order_mesh_matches_static_twin():
    a_bound = max(topology.centrality_bound(_mesh_cfg(mx, my))
                  for mx, my in MESHES)
    big_bound = 4 * max(mx + my for mx, my in MESHES)
    for mx, my in MESHES:
        cfg = _mesh_cfg(mx, my)
        for i, pos in enumerate(_random_placements(cfg, 4, 6, mx * 10 + my)):
            want = np.asarray(activation_order_jnp(pos, cfg))
            got = np.asarray(pareto._activation_order_mesh(
                jnp.asarray(pos), jnp.int32(mx), jnp.int32(my),
                a_bound=a_bound, big_bound=big_bound))
            np.testing.assert_array_equal(got, want,
                                          err_msg=f"mesh {mx}x{my} #{i}")


def test_placement_tables_from_lut_matches_static_twin():
    from repro.core.constants import PHOTONIC_POWER

    for mx, my in MESHES:
        cfg = _mesh_cfg(mx, my)
        g = cfg.max_gateways_per_chiplet
        hop_lut = jnp.asarray(topology.hop_lut(cfg))
        edge_lut = jnp.asarray(topology.edge_lut(cfg))
        mask = jnp.ones((cfg.routers_per_chiplet,), jnp.float32)
        caps = jnp.asarray([-(-cfg.routers_per_chiplet // k)
                            for k in range(1, g + 1)], jnp.int32)
        db_per_hop = float(cfg.router_pitch_mm
                           * PHOTONIC_POWER.waveguide_db_per_mm)
        for pos in _random_placements(cfg, g, 5, mx + my):
            want = placement_tables_jnp(jnp.asarray(pos), cfg)
            got = placement_tables_from_lut_jnp(
                jnp.asarray(pos), hop_lut, edge_lut, mask, caps,
                d_pad=topology.max_hops(cfg) + 1, db_per_hop=db_per_hop)
            for k in ("src_hops", "gw_loss_db"):
                np.testing.assert_allclose(np.asarray(got[k]),
                                           np.asarray(want[k]),
                                           rtol=0, atol=1e-6, err_msg=k)


# ---------------------------------------------------------------------------
# The one-dispatch co-design search
# ---------------------------------------------------------------------------

CODESIGN_KW = dict(n_chiplets=[8, 16], mesh_radix=[4, 4], islands=2,
                   generations=3, population=3, archive=16,
                   knob_grids={"l_m": [0.01, 0.02]}, seed=1)


@pytest.fixture(scope="module")
def base():
    return SimConfig().with_arch(Arch.RESIPI)


@pytest.fixture(scope="module")
def traces(base):
    cfg16 = base.cfg.with_topology(n_chiplets=16)
    return [traffic.generate_trace(app, 6, jax.random.PRNGKey(i), cfg16)
            for i, app in enumerate(("dedup", "streamcluster"))]


@pytest.fixture(scope="module")
def device_run(traces, base):
    """One compiled co-design search + its dispatch-count delta."""
    before = engine_stats()["search_dispatches"]
    result = search_codesign(traces, base, **CODESIGN_KW)
    delta = engine_stats()["search_dispatches"] - before
    return result, delta


def test_codesign_is_one_dispatch(device_run):
    _, delta = device_run
    assert delta == 1


def test_codesign_front_invariants(device_run):
    result, _ = device_run
    assert result["engine"] == "device"
    assert result["islands"] == 2
    assert len(result["front"]) >= 1
    objs = np.array([[e["objectives"][k]
                      for k in ("latency", "power_mw", "energy")]
                     for e in result["front"]])
    assert np.isfinite(objs).all() and (objs > 0).all()
    _assert_no_dominated({"obj": objs,
                          "valid": np.ones(len(objs), bool)})
    for e in result["front"]:
        t = e["topology_index"]
        assert e["topology"]["n_chiplets"] == CODESIGN_KW["n_chiplets"][t]
        assert len(set(e["placement"])) == len(e["placement"])
        assert e["knobs"]["l_m"] == pytest.approx(
            CODESIGN_KW["knob_grids"]["l_m"][e["island"]])
    hist = result["history"]["archive_size"]
    assert hist.shape == (2, CODESIGN_KW["generations"])
    assert np.isfinite(result["history"]["best_scalar"]).all()
    # T * generations * islands * population * workloads
    assert result["candidate_evals"] == (
        2 * CODESIGN_KW["generations"] * CODESIGN_KW["islands"]
        * CODESIGN_KW["population"] * 2)


def test_codesign_front_matches_host_rescore(device_run, traces, base):
    result, _ = device_run
    got = np.array([[e["objectives"][k]
                     for k in ("latency", "power_mw", "energy")]
                    for e in result["front"]])
    want = rescore_front_host(result, traces, base)
    np.testing.assert_allclose(got, want, rtol=1e-6)


def test_codesign_deterministic(device_run, traces, base):
    result, _ = device_run
    again = search_codesign(traces, base, **CODESIGN_KW)
    assert [e["placement"] for e in again["front"]] == \
        [e["placement"] for e in result["front"]]
    np.testing.assert_array_equal(
        np.array([e["objectives"]["latency"] for e in result["front"]]),
        np.array([e["objectives"]["latency"] for e in again["front"]]))


# ---------------------------------------------------------------------------
# Host engine (parity oracle)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def host_run(traces, base):
    return search_codesign(traces, base, engine="host", n_chiplets=[8, 16],
                           mesh_radix=[4, 4], islands=2, generations=2,
                           population=3, archive=16,
                           knob_grids={"l_m": [0.01, 0.02]}, seed=1)


def test_host_engine_invariants(host_run):
    assert host_run["engine"] == "host"
    assert len(host_run["front"]) >= 1
    objs = np.array([[e["objectives"][k]
                      for k in ("latency", "power_mw", "energy")]
                     for e in host_run["front"]])
    _assert_no_dominated({"obj": objs,
                          "valid": np.ones(len(objs), bool)})


def test_host_engine_self_rescore_exact(host_run, traces, base):
    got = np.array([[e["objectives"][k]
                     for k in ("latency", "power_mw", "energy")]
                    for e in host_run["front"]])
    want = rescore_front_host(host_run, traces, base)
    np.testing.assert_allclose(got, want, rtol=1e-6)


def test_host_engine_deterministic(host_run, traces, base):
    again = search_codesign(traces, base, engine="host",
                            n_chiplets=[8, 16], mesh_radix=[4, 4],
                            islands=2, generations=2, population=3,
                            archive=16, knob_grids={"l_m": [0.01, 0.02]},
                            seed=1)
    assert [e["placement"] for e in again["front"]] == \
        [e["placement"] for e in host_run["front"]]


# ---------------------------------------------------------------------------
# Pre-jit validation
# ---------------------------------------------------------------------------

def test_codesign_rejects_gateway_positions_grid(base):
    with pytest.raises(ValueError, match="not a co-design axis"):
        search_codesign(None, base, gateway_positions=[None])


def test_codesign_routes_runtime_fields_to_knob_grids(base):
    with pytest.raises(ValueError, match="knob_grids"):
        search_codesign(None, base, l_m=[0.01])


def test_codesign_rejects_unknown_topology_field(base):
    with pytest.raises(ValueError, match="non-sweepable"):
        search_codesign(None, base, bogus=[1, 2])


def test_codesign_rejects_varying_gateway_width(base):
    with pytest.raises(ValueError, match="must be constant"):
        search_codesign(None, base, n_chiplets=[8, 8],
                        gateways_per_chiplet=[2, 4])


def test_codesign_rejects_knob_length_mismatch(base):
    with pytest.raises(ValueError, match="islands=3"):
        search_codesign(None, base, islands=3,
                        knob_grids={"l_m": [0.01, 0.02]})


def test_codesign_rejects_topology_field_in_knobs(base):
    with pytest.raises(ValueError, match="grid axes"):
        search_codesign(None, base, knob_grids={"n_chiplets": [8, 16]})


def test_codesign_rejects_non_integer_islands(base):
    with pytest.raises(ValueError, match="islands must be an int"):
        search_codesign(None, base, islands=2.5)


def test_codesign_rejects_unknown_engine(base):
    with pytest.raises(ValueError, match="unknown engine"):
        search_codesign(None, base, engine="magic")


def test_codesign_rejects_explicit_coords_config(base):
    hex_sim = dataclasses.replace(base, cfg=topology.hex_config(2))
    with pytest.raises(ValueError, match="derived-mesh"):
        search_codesign(None, hex_sim, n_chiplets=[8])


@pytest.fixture(scope="module")
def small_trace(base):
    return traffic.generate_trace("dedup", 4, jax.random.PRNGKey(3),
                                  base.cfg)


def test_islands_rejects_non_integer_islands(small_trace, base):
    with pytest.raises(ValueError, match="islands must be an int"):
        search_placement_islands(small_trace, base, islands=2.5)


def test_islands_rejects_non_numeric_grid(small_trace, base):
    with pytest.raises(ValueError, match="numeric grid"):
        search_placement_islands(small_trace, base, islands=2,
                                 l_m=["a", "b"])
