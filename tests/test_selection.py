"""Gateway-selection tests: Fig. 8 balanced partition properties."""
import numpy as np
import jax.numpy as jnp

from repro.core.constants import NETWORK
from repro.core.selection import (build_selection_tables, hop_count,
                                  mean_access_hops, select_dest_gateway,
                                  select_source_gateway)

TABLES = build_selection_tables()


def test_every_router_assigned_every_level():
    r = NETWORK.routers_per_chiplet
    for g in range(1, 5):
        assign = TABLES.src_map[g - 1]
        assert assign.shape == (r,)
        assert assign.min() >= 0 and assign.max() < g


def test_balanced_partition_rg():
    """|group| <= ceil(R/g) — the R_g = R/g balance rule of §3.4."""
    r = NETWORK.routers_per_chiplet
    for g in range(1, 5):
        counts = np.bincount(TABLES.src_map[g - 1], minlength=g)
        assert counts.max() <= -(-r // g)
        assert counts.sum() == r


def test_hops_decrease_with_more_gateways():
    """Fig. 3's argument: more gateways => shorter router->gateway walks."""
    hops = TABLES.src_hops
    assert hops[3] < hops[1] < hops[0]
    assert hops[3] < hops[2] < hops[0]


def test_single_gateway_assigns_all_to_it():
    assert set(np.unique(TABLES.src_map[0])) == {0}


def test_runtime_lookups():
    t = TABLES.as_jax()
    gw = select_source_gateway(t, jnp.int32(5), jnp.int32(2))
    assert int(gw) in (0, 1)
    gw = select_dest_gateway(t, jnp.int32(15), jnp.int32(4))
    assert 0 <= int(gw) < 4
    h = mean_access_hops(t, jnp.asarray([1, 4]))
    assert float(h[1]) < float(h[0])


def test_hop_count_is_manhattan():
    a = np.array([0, 0])
    b = np.array([3, 2])
    assert hop_count(a, b) == 5
