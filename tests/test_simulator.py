"""Network-simulator tests: qualitative invariants + paper-claim bands."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import traffic
from repro.core.noc import NocModel
from repro.core.simulator import Arch, SimConfig, simulate, \
    simulate_all_archs


@pytest.fixture(scope="module")
def dedup_trace():
    return traffic.generate_trace("dedup", 40, jax.random.PRNGKey(0))


def test_latency_monotone_in_load():
    noc = NocModel()
    loads = jnp.asarray([0.001, 0.01, 0.02, 0.04])
    lat = noc.inter_chiplet_latency(loads, 4.0, jnp.float32(1.5),
                                    jnp.float32(1.5))
    assert np.all(np.diff(np.asarray(lat)) > 0)


def test_port_limit_caps_wavelength_benefit():
    """Beyond ~3 wavelengths the electronic port binds: 16 lambdas must not
    be materially faster than 4 (the Fig. 3 design-A failure mode)."""
    noc = NocModel()
    l4 = float(noc.gateway_latency(jnp.float32(0.03), 4.0))
    l16 = float(noc.gateway_latency(jnp.float32(0.03), 16.0))
    assert l16 >= 0.95 * l4


def test_resipi_beats_prowaves_on_heavy_traffic():
    tr = traffic.generate_trace("blackscholes", 40, jax.random.PRNGKey(1))
    out = simulate_all_archs(tr)
    assert out["resipi"]["mean_latency"] < out["prowaves"]["mean_latency"]
    assert out["resipi"]["mean_power_mw"] < out["prowaves"]["mean_power_mw"]


def test_resipi_saves_power_vs_all_gateways(dedup_trace):
    out = simulate_all_archs(dedup_trace)
    assert out["resipi"]["mean_power_mw"] < \
        out["resipi_all"]["mean_power_mw"]
    # and pays only a small latency premium for it (Fig. 11.a)
    assert out["resipi"]["mean_latency"] < \
        1.6 * out["resipi_all"]["mean_latency"]


def test_awgr_slowest_at_high_load():
    tr = traffic.generate_trace("canneal", 40, jax.random.PRNGKey(2))
    out = simulate_all_archs(tr)
    assert out["awgr"]["mean_latency"] > out["resipi"]["mean_latency"]


def test_gateway_counts_track_load(dedup_trace):
    heavy = traffic.generate_trace("blackscholes", 40, jax.random.PRNGKey(3))
    light = traffic.generate_trace("facesim", 40, jax.random.PRNGKey(3))
    sim = SimConfig().with_arch(Arch.RESIPI)
    g_heavy = float(simulate(heavy, sim)["summary"]["mean_gateways"])
    g_light = float(simulate(light, sim)["summary"]["mean_gateways"])
    assert g_heavy > g_light


def test_reconfig_energy_only_on_changes(dedup_trace):
    sim = SimConfig().with_arch(Arch.RESIPI_ALL)      # static: no changes
    out = simulate(dedup_trace, sim)["summary"]
    assert float(out["total_reconfig_nj"]) == 0.0


def test_paper_claim_bands():
    """Average over all 8 apps must land near the paper's -37/-25/-53
    (tolerance: +-15 points — the simulator is epoch-scale, not Noxim)."""
    import numpy as np
    rows = {}
    for app in traffic.APP_NAMES:
        tr = traffic.generate_trace(app, 60, jax.random.PRNGKey(1))
        rows[app] = simulate_all_archs(tr)
    lat = np.mean([1 - float(rows[a]["resipi"]["mean_latency"])
                   / float(rows[a]["prowaves"]["mean_latency"])
                   for a in rows])
    pw = np.mean([1 - float(rows[a]["resipi"]["mean_power_mw"])
                  / float(rows[a]["prowaves"]["mean_power_mw"])
                  for a in rows])
    en = np.mean([1 - float(rows[a]["resipi"]["mean_energy"])
                  / float(rows[a]["prowaves"]["mean_energy"])
                  for a in rows])
    assert 0.22 <= lat <= 0.52, lat     # paper: 0.37
    assert 0.10 <= pw <= 0.40, pw       # paper: 0.25
    assert 0.38 <= en <= 0.68, en       # paper: 0.53
