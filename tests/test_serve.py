"""Continuous-batching session-server tests: the robustness envelope.

The load-bearing claims, each pinned:

  * the packed tick is FREE — lane k of the batched dispatch bit-matches
    a standalone `SimSession` stepping the same chunks (replay parity),
    and the whole churning population shares ONE compiled executable;
  * nothing raises out of the serve loop — deadline expiry, retry
    exhaustion, shedding, and eviction all terminate sessions with a
    taxonomy reason and a well-formed partial `summary()` (property
    test);
  * overload degrades gracefully — bounded queues shed by policy with
    backpressure signals, sustained pressure enters coalesced degraded
    mode through a hysteresis band and exits it;
  * a mid-serve fault storm heals without dropping healthy sessions —
    the detector fires on packed-lane telemetry, the blocked re-placement
    swaps into every lane with zero recompiles, and every admitted
    session still completes and bit-matches its replay;
  * `SimSession.swap_placement` composes with ragged/`t_mask`-padded
    chunks — swap mid-stream between padded chunks bit-matches the
    two-phase unpadded run.

Everything is seeded and deterministic.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                                    # minimal containers
    from hypothesis_fallback import given, settings, strategies as st

from repro.core import faults, traffic
from repro.core.gateway_controller import ControllerConfig
from repro.core.simulator import (Arch, SimConfig, SimSession,
                                  engine_stats, init_session_states,
                                  reset_engine_stats, selection_tables_jax,
                                  session_tick)
from repro.serve import policies as P
from repro.serve.engine import SessionServer, replay_standalone
from repro.serve.policies import ServerPolicy
from repro.serve.resilience import DegradationDetector, ResiliencePolicy
from repro.serve.scheduler import SessionRequest


def _sim() -> SimConfig:
    return SimConfig().with_arch(Arch.RESIPI)


def _storm_sim() -> SimConfig:
    """Controller pinned at 4 gateways so a dead router is a real capacity
    loss (same calibration as tests/test_resilience.py)."""
    base = _sim()
    return dataclasses.replace(base, ctl=ControllerConfig(
        l_m=base.ctl.l_m, max_gateways=4, min_gateways=4))


def _tr(seed: int, t: int, scale: float = 1.0) -> dict:
    tr = traffic.generate_trace("dedup", t, jax.random.PRNGKey(seed))
    if scale != 1.0:
        for k in ("ext_load", "mem_load", "int_load"):
            tr[k] = jnp.asarray(tr[k]) * scale
    return tr


RECORD_KEYS = ("latency", "power_mw", "g", "energy", "wavelengths")
PARITY_KEYS = ("mean_latency", "mean_power_mw", "mean_energy",
               "mean_gateways", "valid_intervals")


def _assert_replay_parity(sim, server):
    for sess in server.completed:
        ref = replay_standalone(sim, sess)
        mine = sess.summary()
        for k in PARITY_KEYS:
            assert float(ref[k]) == mine[k], (sess.id, k)


def _assert_well_formed(sess):
    s = sess.summary()
    assert s["termination_reason"] in P.TERMINAL_REASONS
    assert s["valid_intervals"] == float(s["served_intervals"])
    for k in ("mean_latency", "mean_power_mw", "mean_energy"):
        assert np.isfinite(s[k])
        if s["served_intervals"] == 0:
            assert s[k] == 0.0           # the additive identity, not a raise


# ---------------------------------------------------------------------------
# Policy / request validation
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kw", [
    {"lanes": 0}, {"chunk_intervals": 0}, {"retry_backoff_ticks": 0},
    {"throttle_depth": 99}, {"max_queued_intervals": 2},
    {"degrade_hi": 0.2, "degrade_lo": 0.8}, {"degrade_min_priority": 7},
    {"default_deadline_ticks": 0}])
def test_server_policy_rejects_bad_parameters(kw):
    with pytest.raises(ValueError):
        ServerPolicy(**kw)


def test_session_request_rejects_bad_parameters():
    with pytest.raises(ValueError):
        SessionRequest(priority=9)
    with pytest.raises(ValueError):
        SessionRequest(deadline_ticks=0)


# ---------------------------------------------------------------------------
# The packed tick: one executable, bit-transparent lanes
# ---------------------------------------------------------------------------

def test_batched_tick_bit_matches_standalone_sessions():
    """The tentpole invariant at the simulator level: a [B, T] vmapped
    tick's per-lane records are bit-identical to B standalone sessions,
    from ONE scan-body trace."""
    sim = _sim()
    B, T = 3, 6
    trs = [_tr(i, T) for i in range(B)]
    batch = {
        "ext_load": np.stack([np.asarray(t["ext_load"]) for t in trs]),
        "mem_load": np.stack([np.asarray(t["mem_load"]) for t in trs]),
        "int_load": np.stack([np.asarray(t["int_load"]) for t in trs]),
        "ext_frac": np.stack([np.float32(t["ext_frac"]) for t in trs]),
        "t_mask": np.ones((B, T), np.float32),
    }
    states = init_session_states(sim, B)
    tables = selection_tables_jax(sim.cfg)
    reset_engine_stats()
    _, recs, sums = session_tick(states, batch, tables, sim)
    assert engine_stats()["simulate_traces"] == 1
    for i, tr in enumerate(trs):
        ref = SimSession.init(sim).step_chunk(tr)["records"]
        for k in RECORD_KEYS:
            assert np.array_equal(np.asarray(ref[k]), np.asarray(recs[k][i]))


def test_masked_lane_freezes_carry_and_sums_zero():
    sim = _sim()
    B, T = 2, 5
    tr = _tr(0, T)
    batch = {
        "ext_load": np.stack([np.asarray(tr["ext_load"])] * B),
        "mem_load": np.stack([np.asarray(tr["mem_load"])] * B),
        "int_load": np.stack([np.asarray(tr["int_load"])] * B),
        "ext_frac": np.full((B,), np.float32(tr["ext_frac"])),
        "t_mask": np.stack([np.zeros(T), np.ones(T)]).astype(np.float32),
    }
    states = init_session_states(sim, B)
    new_states, _, sums = session_tick(
        states, batch, selection_tables_jax(sim.cfg), sim)
    for a, b in zip(jax.tree.leaves(states), jax.tree.leaves(new_states)):
        assert np.array_equal(np.asarray(a)[0], np.asarray(b)[0]), \
            "masked lane's carry moved"
    assert all(float(v[0]) == 0.0 for v in sums.values())


def test_server_one_executable_across_ticks_and_replay_parity():
    """A churning population (mixed lengths, ragged tails, admissions
    mid-stream) serves end-to-end on ONE compiled executable, and every
    completed session bit-matches its standalone replay."""
    sim = _sim()
    server = SessionServer(sim, ServerPolicy(lanes=3, chunk_intervals=6,
                                             queue_capacity=10))
    reset_engine_stats()
    for i in range(4):
        server.submit(SessionRequest(trace=_tr(i, 5 + 4 * i)))
    server.run(2)
    for i in range(4, 7):                    # late arrivals mid-serve
        server.submit(SessionRequest(trace=_tr(i, 7)))
    server.drain()
    # <= 1: zero if an earlier test already compiled this [B, T] shape,
    # one on a cold cache — never one per tick.
    assert engine_stats()["simulate_traces"] <= 1, engine_stats()
    assert len(server.completed) == 7
    _assert_replay_parity(sim, server)


# ---------------------------------------------------------------------------
# Admission control: signals, shedding taxonomy, displacement, memory
# ---------------------------------------------------------------------------

def test_admission_signals_and_queue_full_shed():
    sim = _sim()
    server = SessionServer(sim, ServerPolicy(
        lanes=1, chunk_intervals=4, queue_capacity=2, throttle_depth=1))
    outs = [server.submit(SessionRequest(trace=_tr(i, 4)))
            for i in range(3)]
    assert outs[0]["signal"] == P.ACCEPT
    assert outs[1]["signal"] == P.THROTTLE          # depth crossed throttle
    assert outs[2]["signal"] == P.SHED
    assert outs[2]["reason"] == P.SHED_QUEUE_FULL
    shed = server.sessions[outs[2]["session_id"]]
    assert shed.termination_reason == P.SHED_QUEUE_FULL
    _assert_well_formed(shed)
    assert server.metrics()["shed_queue_full"] == 1


def test_premium_displaces_queued_batch_work():
    sim = _sim()
    server = SessionServer(sim, ServerPolicy(
        lanes=1, chunk_intervals=4, queue_capacity=2))
    ids = [server.submit(SessionRequest(
        trace=_tr(i, 4), priority=P.PRIORITY_BATCH))["session_id"]
        for i in range(2)]
    out = server.submit(SessionRequest(trace=_tr(9, 4),
                                       priority=P.PRIORITY_PREMIUM))
    assert out["signal"] in (P.ACCEPT, P.THROTTLE)
    # The youngest batch session was displaced; the premium one is queued.
    victim = server.sessions[ids[1]]
    assert victim.termination_reason == P.SHED_QUEUE_FULL
    assert server.metrics()["displaced"] == 1
    assert any(s.priority == P.PRIORITY_PREMIUM for s in server.queue)
    # An equal-priority submission cannot displace — it sheds instead.
    out2 = server.submit(SessionRequest(trace=_tr(10, 4),
                                        priority=P.PRIORITY_BATCH))
    assert out2["signal"] == P.SHED


def test_memory_budget_sheds_by_queued_intervals():
    sim = _sim()
    server = SessionServer(sim, ServerPolicy(
        lanes=1, chunk_intervals=4, queue_capacity=10,
        max_queued_intervals=8))
    a = server.submit(SessionRequest(trace=_tr(0, 8)))
    assert a["signal"] == P.ACCEPT
    b = server.submit(SessionRequest(trace=_tr(1, 8)))   # 16 > 8: refused
    assert b["signal"] == P.SHED and b["reason"] == P.SHED_MEMORY
    assert server.metrics()["shed_memory"] == 1
    _assert_well_formed(server.sessions[b["session_id"]])


# ---------------------------------------------------------------------------
# Deadlines: queued and mid-stream expiry with partial summaries
# ---------------------------------------------------------------------------

def test_deadline_expires_queued_and_running_sessions():
    sim = _sim()
    server = SessionServer(sim, ServerPolicy(
        lanes=1, chunk_intervals=4, queue_capacity=8))
    # One long resident session and two queued behind it, all deadline 2.
    ids = [server.submit(SessionRequest(
        trace=_tr(i, 16), deadline_ticks=2))["session_id"]
        for i in range(3)]
    server.run(4)
    running, q1, q2 = (server.sessions[i] for i in ids)
    # The resident session served 2 chunks then expired mid-stream.
    assert running.termination_reason == P.DEADLINE_EXPIRED
    assert 0 < running.served_intervals < 16
    _assert_well_formed(running)
    # The queued ones expired without serving anything — still well-formed.
    for sess in (q1, q2):
        assert sess.termination_reason == P.DEADLINE_EXPIRED
        assert sess.served_intervals == 0
        _assert_well_formed(sess)
    assert server.metrics()["deadline_expired"] == 3


# ---------------------------------------------------------------------------
# Retry: transient failures roll back, back off, and bound out
# ---------------------------------------------------------------------------

def test_transient_failures_retry_then_bit_match():
    """A lane whose first two step attempts fail retries with backoff and
    completes — and the session STILL bit-matches a clean standalone
    replay (the rollback restored the carry exactly)."""
    sim = _sim()
    fails = {"s_flaky": 2}

    def hook(tick, sess):
        if fails.get(sess.id, 0) > 0:
            fails[sess.id] -= 1
            return True
        return False

    server = SessionServer(
        sim, ServerPolicy(lanes=2, chunk_intervals=4, queue_capacity=4,
                          retry_limit=3),
        step_fault_hook=hook)
    server.submit(SessionRequest(trace=_tr(0, 8), session_id="s_flaky"))
    server.submit(SessionRequest(trace=_tr(1, 8), session_id="s_ok"))
    server.drain()
    m = server.metrics()
    assert m["retries"] == 2
    assert len(server.completed) == 2
    flaky = server.sessions["s_flaky"]
    assert flaky.termination_reason == P.COMPLETED
    assert flaky.served_intervals == 8
    _assert_replay_parity(sim, server)


def test_retry_exhaustion_terminates_with_partial_summary():
    sim = _sim()

    def hook(tick, sess):
        return sess.id == "s_dead" and len(sess.served_log) >= 1

    server = SessionServer(
        sim, ServerPolicy(lanes=2, chunk_intervals=4, queue_capacity=4,
                          retry_limit=2, retry_backoff_ticks=1),
        step_fault_hook=hook)
    server.submit(SessionRequest(trace=_tr(0, 12), session_id="s_dead"))
    server.submit(SessionRequest(trace=_tr(1, 12), session_id="s_ok"))
    server.drain()
    dead = server.sessions["s_dead"]
    assert dead.termination_reason == P.RETRY_EXHAUSTED
    assert dead.served_intervals == 4          # first chunk landed
    _assert_well_formed(dead)
    assert server.sessions["s_ok"].termination_reason == P.COMPLETED
    assert server.metrics()["retry_exhausted"] == 1
    _assert_replay_parity(sim, server)         # the healthy one


def test_exponential_backoff_parks_the_lane():
    """Backoff doubles per attempt: with base 2 and retry_limit 3, the
    failing session is parked (masked lane) on the expected ticks."""
    sim = _sim()
    attempts = []

    def hook(tick, sess):
        attempts.append(tick)
        return True

    server = SessionServer(
        sim, ServerPolicy(lanes=1, chunk_intervals=4, queue_capacity=2,
                          retry_limit=3, retry_backoff_ticks=2),
        step_fault_hook=hook)
    server.submit(SessionRequest(trace=_tr(0, 4)))
    server.run(16)
    # Attempts at t, then +2, +4, +8 (exponential), then exhausted.
    assert len(attempts) == 4
    assert [b - a for a, b in zip(attempts, attempts[1:])] == [2, 4, 8]
    assert server.metrics()["retry_exhausted"] == 1


# ---------------------------------------------------------------------------
# Idle eviction (open streams) and streaming feed
# ---------------------------------------------------------------------------

def test_open_stream_feed_close_and_idle_eviction():
    sim = _sim()
    server = SessionServer(sim, ServerPolicy(
        lanes=2, chunk_intervals=4, queue_capacity=4, idle_evict_ticks=3))
    # Stream A: fed, closed, completes. Stream B: starves, evicted.
    a = server.submit(SessionRequest(session_id="a"))
    b = server.submit(SessionRequest(session_id="b"))
    assert a["signal"] == P.ACCEPT and b["signal"] == P.ACCEPT
    server.feed("a", _tr(0, 8))
    server.feed("b", _tr(1, 4))
    server.run(2)
    server.close("a")
    server.run(6)
    assert server.sessions["a"].termination_reason == P.COMPLETED
    evicted = server.sessions["b"]
    assert evicted.termination_reason == P.IDLE_EVICTED
    assert evicted.served_intervals == 4       # what it fed, it got
    _assert_well_formed(evicted)
    assert server.metrics()["idle_evicted"] == 1


# ---------------------------------------------------------------------------
# Graceful degradation: hysteresis band + chunk coalescing
# ---------------------------------------------------------------------------

def test_degraded_mode_enters_coalesces_sheds_and_exits():
    sim = _sim()
    server = SessionServer(sim, ServerPolicy(
        lanes=2, chunk_intervals=4, queue_capacity=4, degrade_hi=0.5,
        degrade_lo=0.25, degrade_patience=2, degrade_coalesce=3,
        degrade_min_priority=P.PRIORITY_STANDARD))
    for i in range(6):
        server.submit(SessionRequest(trace=_tr(i, 12)))
    server.run(2)
    assert server.degraded, server.metrics()
    # While degraded: batch-class submissions shed at the door...
    out = server.submit(SessionRequest(trace=_tr(9, 4),
                                       priority=P.PRIORITY_BATCH))
    assert out["signal"] == P.SHED and out["reason"] == P.SHED_PRIORITY
    # ...and ticks coalesce chunks to drain residents faster.
    before = server.metrics()["coalesced_dispatches"]
    server.tick()
    assert server.metrics()["coalesced_dispatches"] > before
    server.drain()
    server.run(2 * 2)          # empty ticks let the hysteresis unlatch
    assert not server.degraded                 # pressure gone: mode exits
    m = server.metrics()
    assert m["degraded_ticks"] > 0 and m["shed_priority"] == 1
    # Degradation never dropped an admitted session.
    assert len(server.completed) == m["admitted"]
    _assert_replay_parity(sim, server)


# ---------------------------------------------------------------------------
# Fault storm mid-serve: heal without dropping healthy sessions
# ---------------------------------------------------------------------------

def test_fault_storm_heals_lanes_without_dropping_sessions():
    sim = _storm_sim()
    policy = ServerPolicy(lanes=2, chunk_intervals=8, queue_capacity=4)
    victims = SessionServer(sim, policy).placement[:2]
    horizon = 24 * 8
    env = faults.FaultInjector(
        [faults.GatewayFault(start=24, position=p) for p in victims],
        horizon)
    server = SessionServer(
        sim, policy, fault_env=env,
        resilience=ResiliencePolicy(threshold_frac=0.10, hysteresis=2,
                                    cooldown=1, search_generations=4,
                                    search_population=6))
    reset_engine_stats()
    for i in range(2):
        server.submit(SessionRequest(trace=_tr(i, 64, scale=2.0)))
    server.drain()
    m = server.metrics()
    # The storm was detected and healed off the dead routers, live.
    assert m["heals"] >= 1
    assert not (set(server.placement) & set(victims)), server.placement
    assert m["total_pcm_nj"] > 0.0
    # No healthy session dropped: everything admitted completed in full.
    assert len(server.completed) == 2
    assert all(s.served_intervals == 64 for s in server.completed)
    # Post-heal telemetry re-entered the band (availability recovered).
    post_heal = [e for e in server.events
                 if e.get("healed") is None and e["tick"] >
                 next(ev["tick"] for ev in server.events if ev.get("healed"))]
    assert any(not e["breach"] for e in post_heal)
    # Two executables max (clean tick + fault-twin tick), zero recompiles
    # from the swap.
    assert engine_stats()["simulate_traces"] <= 2, engine_stats()
    # And the storm-crossing sessions still bit-match their replay (same
    # shared frames, same placements, same order).
    _assert_replay_parity(sim, server)


# ---------------------------------------------------------------------------
# Satellite: SimSession.swap_placement under ragged/padded chunks
# ---------------------------------------------------------------------------

def test_swap_placement_between_padded_chunks_bit_matches_two_phase():
    """Swap mid-stream between two t_mask-padded chunks == the equivalent
    two-phase unpadded run (one chunk per phase), bit for bit."""
    sim = _sim()
    tr = _tr(0, 20)
    alt = ((1, 1), (2, 2), (1, 2), (2, 1))

    # Padded-chunk session: 8-interval chunks (last is 4 valid + 4 masked),
    # placement swapped after the second chunk (16 intervals in).
    padded = SimSession.init(sim)
    recs_p = []
    for i, ch in enumerate(traffic.chunk_trace(tr, 8, pad=True)):
        if i == 2:
            padded.swap_placement(alt)
        recs_p.append(padded.step_chunk(ch)["records"])
    cat = jax.tree.map(lambda *xs: jnp.concatenate(xs), *recs_p)

    # Two-phase reference: each phase one unpadded chunk (ext_frac is a
    # 0-d scalar and rides through unsliced).
    def phase(lo, hi):
        return {k: (v[lo:hi] if getattr(v, "ndim", 0) >= 1 else v)
                for k, v in tr.items()}

    ref = SimSession.init(sim)
    recs_a = ref.step_chunk(phase(0, 16))["records"]
    ref.swap_placement(alt)
    recs_b = ref.step_chunk(phase(16, 20))["records"]

    valid = np.concatenate([np.ones(16, bool), np.ones(4, bool),
                            np.zeros(4, bool)])
    for k in RECORD_KEYS:
        got = np.asarray(cat[k])[valid]
        want = np.concatenate([np.asarray(recs_a[k]), np.asarray(recs_b[k])])
        assert np.array_equal(got, want), f"records[{k}] diverged"
    for k in PARITY_KEYS:
        assert float(padded.summary()[k]) == float(ref.summary()[k]), k
    assert padded.intervals_seen == 20


def test_swap_placement_before_first_chunk_equals_fresh_session():
    sim = _sim()
    tr = _tr(1, 12)
    alt = ((0, 0), (3, 3), (0, 3), (3, 0))
    swapped = SimSession.init(sim)
    swapped.swap_placement(alt)
    fresh = SimSession.init(dataclasses.replace(
        sim, cfg=sim.cfg.with_placement(alt)))
    a = swapped.step_chunk(tr)["records"]
    b = fresh.step_chunk(tr)["records"]
    for k in RECORD_KEYS:
        assert np.array_equal(np.asarray(a[k]), np.asarray(b[k])), k


# ---------------------------------------------------------------------------
# Detector extraction: ResilienceRuntime semantics preserved
# ---------------------------------------------------------------------------

def test_degradation_detector_threshold_hysteresis_cooldown():
    det = DegradationDetector(ResiliencePolicy(
        threshold_frac=0.10, hysteresis=2, cooldown=2))
    assert det.update(100.0)["breach"] is False      # seeds the baseline
    assert det.update(105.0)["breach"] is False      # in band: EWMA tracks
    assert det.update(130.0) == {
        "latency": 130.0, "baseline": det.baseline, "breach": True,
        "fire": False}
    out = det.update(130.0)
    assert out["breach"] and out["fire"]             # hysteresis met
    assert det.update(130.0)["fire"] is False        # cooldown holds fire
    assert det.update(130.0)["fire"] is False
    assert det.update(130.0)["fire"]                 # cooldown elapsed
    # Baseline froze through the whole breach run.
    assert det.baseline == pytest.approx(101.25)


# ---------------------------------------------------------------------------
# Property: the loop never raises; every ending is taxonomized + summary
# ---------------------------------------------------------------------------

@settings(max_examples=8, deadline=None)
@given(st.integers(min_value=1, max_value=5),
       st.integers(min_value=0, max_value=3),
       st.integers(min_value=1, max_value=4),
       st.integers(min_value=0, max_value=6),
       st.integers(min_value=0, max_value=1 << 16))
def test_property_every_session_ends_well_formed(
        n_sessions, queue_capacity, deadline, fail_mod, seed):
    """Whatever the arrival mix, deadlines, queue bound, and transient
    failure pattern: tick()/drain() never raise, every session ends with
    a taxonomy reason, and every summary is well-formed with
    valid_intervals == what was actually served."""
    rng = np.random.default_rng(seed)

    def hook(tick, sess):
        return fail_mod > 0 and (tick + hash(sess.id)) % (fail_mod + 2) == 0

    # Fixed lanes/chunk so every example reuses one compiled executable.
    server = SessionServer(
        _sim(), ServerPolicy(lanes=2, chunk_intervals=4,
                             queue_capacity=queue_capacity,
                             retry_limit=2, retry_backoff_ticks=1,
                             default_deadline_ticks=deadline),
        step_fault_hook=hook)
    for i in range(n_sessions):
        t = int(rng.integers(1, 10))
        server.submit(SessionRequest(trace=_tr(int(rng.integers(99)), t),
                                     priority=int(rng.integers(3))))
    server.drain()
    assert server.sessions_in_flight == 0 and len(server.queue) == 0
    assert len(server.sessions) == n_sessions
    for sess in server.sessions.values():
        assert sess.terminal
        _assert_well_formed(sess)
    m = server.metrics()
    assert m["completed"] + m["deadline_expired"] + m["retry_exhausted"] \
        + m["shed_queue_full"] + m["shed_memory"] + m["shed_priority"] \
        == n_sessions


# ---------------------------------------------------------------------------
# Destination-carrying sessions (PR 9: dest threads through the packed tick)
# ---------------------------------------------------------------------------

def _ring_dest(c: int) -> np.ndarray:
    """Each chiplet sends everything to its ring neighbour — maximally
    far from the uniform matrix the dest-free path assumes."""
    d = np.zeros((c, c), np.float32)
    for i in range(c):
        d[i, (i + 1) % c] = 1.0
    return d


def test_dest_session_completes_and_bit_matches_replay():
    sim = _sim()
    tr = dict(_tr(0, 8), dest=_ring_dest(sim.cfg.n_chiplets))
    server = SessionServer(sim, ServerPolicy(lanes=2, chunk_intervals=4))
    sid = server.submit(SessionRequest(trace=tr))["session_id"]
    server.drain()
    assert server.sessions[sid].status == "completed"
    _assert_replay_parity(sim, server)


def test_dest_session_numbers_differ_from_dest_free():
    sim = _sim()
    plain = SessionServer(sim, ServerPolicy(lanes=1, chunk_intervals=4))
    p = plain.submit(SessionRequest(trace=_tr(0, 8)))["session_id"]
    plain.drain()
    routed = SessionServer(sim, ServerPolicy(lanes=1, chunk_intervals=4))
    r = routed.submit(SessionRequest(
        trace=dict(_tr(0, 8), dest=_ring_dest(sim.cfg.n_chiplets))
    ))["session_id"]
    routed.drain()
    a, b = plain.sessions[p].summary(), routed.sessions[r].summary()
    assert any(a[k] != b[k] for k in PARITY_KEYS)


def test_mixed_dest_and_plain_lanes_both_complete_with_parity():
    """One server, one dest-free and one dest-carrying session: each lane
    group gets its own dispatch, both bit-match their standalone replays,
    and the dest lane leaves the plain lane's numbers untouched."""
    sim = _sim()
    server = SessionServer(sim, ServerPolicy(lanes=3, chunk_intervals=4))
    plain_sid = server.submit(SessionRequest(trace=_tr(1, 8)))["session_id"]
    dest_sid = server.submit(SessionRequest(
        trace=dict(_tr(2, 8), dest=_ring_dest(sim.cfg.n_chiplets))
    ))["session_id"]
    server.drain()
    assert server.sessions[plain_sid].status == "completed"
    assert server.sessions[dest_sid].status == "completed"
    _assert_replay_parity(sim, server)
    ref = SessionServer(sim, ServerPolicy(lanes=3, chunk_intervals=4))
    rid = ref.submit(SessionRequest(trace=_tr(1, 8)))["session_id"]
    ref.drain()
    mine = server.sessions[plain_sid].summary()
    theirs = ref.sessions[rid].summary()
    for k in PARITY_KEYS:
        assert mine[k] == theirs[k], k


def test_batched_dest_matrix_is_rejected():
    sim = _sim()
    tr = dict(_tr(0, 6),
              dest=np.stack([_ring_dest(sim.cfg.n_chiplets)] * 2))
    server = SessionServer(sim, ServerPolicy(lanes=1, chunk_intervals=4))
    with pytest.raises(ValueError, match="batched destination"):
        server.submit(SessionRequest(trace=tr))
