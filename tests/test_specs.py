"""Dry-run spec machinery: abstract inputs + pspecs for every cell build
without touching jax device state (shapes only)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_NAMES, SHAPES, cell_applicable, get_config
from repro.launch import specs as S
from repro.sharding.rules import Rules


class FakeMesh:
    def __init__(self, sizes):
        self.axis_names = tuple(sizes)
        import numpy as np
        self.devices = np.empty(tuple(sizes.values()))
        self.size = int(self.devices.size)


RULES = Rules(FakeMesh({"data": 16, "model": 16}))
RULES3 = Rules(FakeMesh({"pod": 2, "data": 16, "model": 16}))


@pytest.mark.parametrize("arch", ARCH_NAMES)
@pytest.mark.parametrize("cellname", [s.name for s in SHAPES])
@pytest.mark.parametrize("rules", [RULES, RULES3], ids=["1pod", "2pod"])
def test_cell_specs_build(arch, cellname, rules):
    cfg = get_config(arch)
    cell = next(s for s in SHAPES if s.name == cellname)
    ok, _ = cell_applicable(cfg, cell)
    if not ok:
        pytest.skip("cell not applicable")
    if cell.kind in ("train", "prefill"):
        batch, pspecs = S.batch_specs(cfg, cell, rules)
        assert set(batch) == set(pspecs)
        for k, v in batch.items():
            assert isinstance(v, jax.ShapeDtypeStruct)
            assert v.shape[0] == cell.global_batch
    else:
        toks, tspec = S.decode_tokens_specs(cfg, cell, rules)
        assert toks.shape == (cell.global_batch, 1)
        caches, cspecs = S.decode_cache_specs(cfg, cell, rules)
        # structures must match exactly (pjit requirement)
        jax.tree.structure(caches) == jax.tree.structure(
            cspecs, is_leaf=lambda x: x is None)


def test_vlm_text_length_accounts_for_patches():
    cfg = get_config("pixtral-12b")
    cell = next(s for s in SHAPES if s.name == "train_4k")
    batch, _ = S.batch_specs(cfg, cell, RULES)
    assert batch["tokens"].shape[1] + cfg.frontend_embeds == cell.seq_len


def test_long_500k_only_subquadratic():
    cell = next(s for s in SHAPES if s.name == "long_500k")
    runnable = [a for a in ARCH_NAMES
                if cell_applicable(get_config(a), cell)[0]]
    assert sorted(runnable) == ["mamba2-130m", "zamba2-7b"]
