"""Deterministic stand-in for `hypothesis` in minimal containers.

The real package is preferred everywhere (test modules import it first and
fall back here only on ImportError). The shim re-runs each @given test body
over a fixed number of seeded pseudo-random samples, drawing boundary values
first — no shrinking or failure database, but the property tests stay
executable instead of erroring at collection when hypothesis is absent.
"""
from __future__ import annotations

import functools
import random

_DEFAULT_MAX_EXAMPLES = 12


class _Strategy:
    """A draw function plus boundary examples emitted before random ones."""

    def __init__(self, draw, edges=()):
        self._draw = draw
        self.edges = list(edges)

    def example(self, rng: random.Random, i: int):
        if i < len(self.edges):
            return self.edges[i]
        return self._draw(rng)


class strategies:
    @staticmethod
    def integers(min_value: int = 0, max_value: int = 1 << 16) -> _Strategy:
        return _Strategy(lambda rng: rng.randint(min_value, max_value),
                         edges=(min_value, max_value))

    @staticmethod
    def floats(min_value: float = 0.0, max_value: float = 1.0,
               allow_nan: bool = False, allow_infinity: bool = False,
               **_ignored) -> _Strategy:
        return _Strategy(lambda rng: rng.uniform(min_value, max_value),
                         edges=(min_value, max_value))

    @staticmethod
    def booleans() -> _Strategy:
        return _Strategy(lambda rng: rng.random() < 0.5, edges=(False, True))

    @staticmethod
    def sampled_from(seq) -> _Strategy:
        seq = list(seq)
        return _Strategy(lambda rng: rng.choice(seq), edges=seq[:2])

    @staticmethod
    def lists(elements: _Strategy, min_size: int = 0,
              max_size: int = 10) -> _Strategy:
        def draw(rng):
            size = rng.randint(min_size, max_size)
            return [elements.example(rng, len(elements.edges) + 1)
                    for _ in range(size)]
        edge = [elements.example(random.Random(0), i % max(
            len(elements.edges), 1)) for i in range(min_size)]
        return _Strategy(draw, edges=(edge,))


def given(*arg_strats, **kw_strats):
    def deco(fn):
        def wrapper():
            n = getattr(wrapper, "_max_examples",
                        getattr(fn, "_max_examples", _DEFAULT_MAX_EXAMPLES))
            rng = random.Random(f"{fn.__module__}.{fn.__name__}")
            for i in range(n):
                args = [s.example(rng, i) for s in arg_strats]
                kwargs = {k: s.example(rng, i) for k, s in kw_strats.items()}
                fn(*args, **kwargs)
        # No functools.wraps: pytest must see a zero-arg signature, or it
        # would try to inject the strategy parameters as fixtures.
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        return wrapper
    return deco


def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES, deadline=None,
             **_ignored):
    def deco(fn):
        fn._max_examples = max_examples
        return fn
    return deco
