"""Property tests for the validate_trace value gate.

`validate_trace` is the pre-jit front door for every transform and engine
entry point: malformed values (NaN, negative loads, non-numeric dtypes)
must be rejected HERE with a named key, because past the boundary the
compiled scan silently propagates them into every summary. Properties:

  * any well-formed generated trace passes, wherever NaN-free and
    non-negative — including zeros and large-but-finite loads;
  * poisoning ANY single element of ANY core array with NaN raises and
    names the key;
  * making ANY single element negative raises and names the key;
  * tracers (inside jit) skip the value scan — validation still succeeds
    under jit where values are abstract.
"""
try:                                     # pragma: no cover - env dependent
    from hypothesis import given, settings, strategies as st
except ImportError:                      # minimal container: use shim
    from hypothesis_fallback import given, settings, strategies as st

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import traffic
from repro.core.traffic.transform import TRACE_KEYS, validate_trace

ARRAY_KEYS = ("ext_load", "mem_load", "int_load")


def _trace(seed: int = 0, t: int = 6) -> dict:
    return traffic.generate_trace("dedup", t, jax.random.PRNGKey(seed))


@given(seed=st.integers(min_value=0, max_value=2**31 - 1),
       t=st.integers(min_value=1, max_value=16))
@settings(max_examples=20, deadline=None)
def test_generated_traces_always_validate(seed, t):
    tr = _trace(seed, t)
    assert validate_trace(tr) is tr


@given(key=st.sampled_from(ARRAY_KEYS),
       frac=st.floats(min_value=0.0, max_value=1.0),
       seed=st.integers(min_value=0, max_value=1000))
@settings(max_examples=25, deadline=None)
def test_single_nan_anywhere_is_rejected_and_named(key, frac, seed):
    tr = {k: np.asarray(v) if k in ARRAY_KEYS else v
          for k, v in _trace(seed % 7).items()}
    flat = tr[key].reshape(-1).copy()
    flat[int(frac * (flat.size - 1))] = np.nan
    tr[key] = flat.reshape(tr[key].shape)
    with pytest.raises(ValueError, match=f"{key}.*NaN"):
        validate_trace(tr)


@given(key=st.sampled_from(ARRAY_KEYS),
       frac=st.floats(min_value=0.0, max_value=1.0),
       mag=st.floats(min_value=1e-6, max_value=1e6))
@settings(max_examples=25, deadline=None)
def test_single_negative_anywhere_is_rejected_and_named(key, frac, mag):
    tr = {k: np.asarray(v) if k in ARRAY_KEYS else v
          for k, v in _trace().items()}
    flat = tr[key].reshape(-1).copy()
    flat[int(frac * (flat.size - 1))] = -mag
    tr[key] = flat.reshape(tr[key].shape)
    with pytest.raises(ValueError, match=f"{key}.*negative"):
        validate_trace(tr)


@given(scale=st.floats(min_value=0.0, max_value=1e12))
@settings(max_examples=15, deadline=None)
def test_nonnegative_scaling_keeps_a_trace_valid(scale):
    # Zero and huge-but-finite loads are legitimate (idle / stress traces):
    # the gate rejects ill-formed values, not extreme ones.
    tr = _trace()
    scaled = dict(tr, **{k: jnp.asarray(tr[k]) * scale for k in ARRAY_KEYS})
    assert validate_trace(scaled) is scaled


def test_nan_ext_frac_is_rejected():
    tr = dict(_trace(), ext_frac=float("nan"))
    with pytest.raises(ValueError, match="ext_frac.*NaN"):
        validate_trace(tr)


def test_non_numeric_dtype_is_rejected():
    tr = dict(_trace())
    tr["mem_load"] = np.array(["a"] * int(np.shape(tr["mem_load"])[0]))
    with pytest.raises(ValueError, match="mem_load.*numeric"):
        validate_trace(tr)


def test_missing_key_and_non_dict_still_raise():
    with pytest.raises(TypeError, match="trace dict"):
        validate_trace([1, 2, 3])
    tr = dict(_trace())
    del tr["int_load"]
    with pytest.raises(ValueError, match="int_load"):
        validate_trace(tr)


def test_tracers_skip_the_value_scan_under_jit():
    tr = _trace()

    @jax.jit
    def scale(ext, mem, intra, frac):
        t = dict(tr, ext_load=ext, mem_load=mem, int_load=intra,
                 ext_frac=frac)
        validate_trace(t)            # abstract values: must not raise
        return t["ext_load"].sum()

    out = scale(tr["ext_load"], tr["mem_load"], tr["int_load"],
                jnp.float32(tr["ext_frac"]))
    assert np.isfinite(float(out))


def test_validation_rejects_values_before_the_engine_sees_them():
    """End-to-end: simulate() refuses a poisoned trace pre-jit."""
    from repro.core.simulator import SimConfig, simulate

    tr = {k: np.array(v) if k in ARRAY_KEYS else v
          for k, v in _trace().items()}
    tr["ext_load"][0, 0] = np.nan
    with pytest.raises(ValueError, match="NaN"):
        simulate(tr, SimConfig())
