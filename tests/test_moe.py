"""MoE dispatch/combine correctness and load-stat properties."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # minimal container: use shim
    from hypothesis_fallback import given, settings, strategies as st

from repro.configs import get_smoke_config
from repro.models.moe import build_dispatch, moe_block, route_topk
from repro.models.params import init_params


def test_dispatch_capacity_bound():
    experts = jnp.asarray([[0], [0], [0], [1]])      # 3 tokens want e0
    gather, choice, combine, kept = build_dispatch(experts, n_experts=2, capacity=2)
    assert gather.shape == (2, 2)
    # only 2 of the 3 e0-tokens kept
    assert int(kept.sum()) == 3
    assert int(kept[:3].sum()) == 2


def test_dispatch_fifo_tiebreak():
    """Earlier tokens win slots — the paper's per-packet FIFO analogue."""
    experts = jnp.asarray([[0], [0], [0]])
    gather, _, combine, kept = build_dispatch(experts, n_experts=1, capacity=2)
    np.testing.assert_array_equal(np.asarray(kept[:, 0]),
                                  [True, True, False])
    assert set(np.asarray(gather[0]).tolist()) == {0, 1}


@settings(max_examples=20, deadline=None)
@given(t=st.integers(2, 40), e=st.integers(2, 8), k=st.integers(1, 2))
def test_dispatch_slots_consistent(t, e, k):
    key = jax.random.PRNGKey(t * 31 + e)
    experts = jax.random.randint(key, (t, k), 0, e)
    cap = max(1, (t * k) // e)
    gather, choice, combine, kept = build_dispatch(experts, e, cap)
    g = np.asarray(gather)
    # every non-empty slot points at a real token whose choice matches
    for ei in range(e):
        for c in range(cap):
            tok = g[ei, c]
            if tok < t:
                ch = int(np.asarray(choice)[ei, c])
                assert int(np.asarray(experts)[tok, ch]) == ei
    # combine is the inverse map: kept choices round-trip through slots
    cmb, kp = np.asarray(combine), np.asarray(kept)
    for tok in range(t):
        for j in range(k):
            if kp[tok, j]:
                ei, c = divmod(int(cmb[tok, j]), cap)
                assert g[ei, c] == tok


def test_moe_block_matches_dense_reference():
    """With capacity ample, sort-based MoE == explicit per-token compute."""
    cfg = get_smoke_config("grok-1-314b")
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    from repro.models.moe import moe_spec
    p = init_params(moe_spec(cfg), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model),
                          jnp.float32).astype(jnp.bfloat16)
    y, stats = moe_block(p, x, cfg)

    # dense reference
    xt = x.reshape(-1, cfg.d_model)
    logits = jnp.einsum("td,de->te", xt, p["router"].astype(jnp.bfloat16))
    gates, experts = route_topk(logits, cfg.moe.top_k)
    y_ref = jnp.zeros((xt.shape[0], cfg.d_model), jnp.float32)
    for tok in range(xt.shape[0]):
        acc = jnp.zeros((cfg.d_model,), jnp.float32)
        for j in range(cfg.moe.top_k):
            e = int(experts[tok, j])
            h = xt[tok] @ p["wi"][e].astype(jnp.bfloat16)
            h = jax.nn.gelu(h)
            out = h @ p["wo"][e].astype(jnp.bfloat16)
            acc += float(gates[tok, j]) * out.astype(jnp.float32)
        y_ref = y_ref.at[tok].set(acc)
    np.testing.assert_allclose(
        np.asarray(y.reshape(-1, cfg.d_model), np.float32),
        np.asarray(y_ref), atol=5e-2, rtol=5e-2)
    assert float(stats["drop_frac"]) == 0.0


def test_moe_load_stats():
    cfg = get_smoke_config("kimi-k2-1t-a32b")
    from repro.models.moe import moe_spec
    p = init_params(moe_spec(cfg), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model)
                          ).astype(jnp.bfloat16)
    y, stats = moe_block(p, x, cfg)
    tpe = np.asarray(stats["tokens_per_expert"])
    assert tpe.sum() <= 2 * 16 * cfg.moe.top_k + 1e-6
    assert float(stats["aux_loss"]) > 0.0
    assert y.shape == x.shape
