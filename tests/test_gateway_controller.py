"""Controller tests: Eqs. 5-10, hysteresis properties, Fig. 6 table."""
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # minimal container: use shim
    from hypothesis_fallback import given, settings, strategies as st

from repro.core.gateway_controller import (ControllerConfig,
                                           ControllerState,
                                           average_gateway_load, epoch_step,
                                           scan_controller, t_n, t_p,
                                           update_gateways)

CFG = ControllerConfig(l_m=0.0152, max_gateways=4)


def test_eq5_average_load():
    # L = P / (T * g)
    load = average_gateway_load(jnp.float32(3040.0), jnp.float32(1e5),
                                jnp.int32(2))
    assert float(load) == pytest.approx(0.0152)


def test_fig6_threshold_table():
    """T_N_g = L_m (1 - 1/g): 0, Lm/2, 2Lm/3, 3Lm/4 for g=1..4 (Fig. 6)."""
    expect = [0.0, 0.0076, 0.0152 * 2 / 3, 0.0114]
    got = [float(t_n(jnp.int32(g), CFG)) for g in (1, 2, 3, 4)]
    np.testing.assert_allclose(got, expect, rtol=1e-5)
    assert float(t_p(CFG)) == pytest.approx(0.0152)


def test_eq6_increase_on_overload():
    g = jnp.asarray([1, 2, 3, 4])
    load = jnp.full((4,), 0.02)          # > L_m everywhere
    out = update_gateways(g, load, CFG)
    np.testing.assert_array_equal(np.asarray(out), [2, 3, 4, 4])  # capped


def test_eq7_decrease_on_underload():
    g = jnp.asarray([1, 2, 3, 4])
    load = jnp.full((4,), 0.001)         # < T_N for g >= 2
    out = update_gateways(g, load, CFG)
    np.testing.assert_array_equal(np.asarray(out), [1, 1, 2, 3])  # floored


@settings(max_examples=30, deadline=None)
@given(st.floats(min_value=0.0, max_value=0.1, allow_nan=False),
       st.integers(min_value=1, max_value=4))
def test_hysteresis_bands_disjoint(load, g):
    """T_N_g < T_P for all g, so a single load can never trigger both an
    increase and a decrease — the controller cannot oscillate within one
    interval."""
    assert float(t_n(jnp.int32(g), CFG)) < float(t_p(CFG))
    out = int(update_gateways(jnp.asarray([g]), jnp.asarray([load]),
                              CFG)[0])
    assert abs(out - g) <= 1


@settings(max_examples=15, deadline=None)
@given(st.floats(min_value=0.0005, max_value=0.012))
def test_steady_load_reaches_fixed_point(load):
    """Under constant load the controller converges and stays put."""
    trace = jnp.full((30, 1), load)
    recs = scan_controller(trace, CFG, interval_cycles=1e6)
    g = np.asarray(recs["g_after"])[:, 0]
    # after convergence, g stays constant
    tail = g[-5:]
    assert np.all(tail == tail[0])
    # and the steady g's per-gateway load sits inside the hysteresis band
    g_star = int(tail[0])
    per_gw = load / g_star
    if g_star < 4:
        assert per_gw <= CFG.l_m + 1e-9
    if g_star > 1:
        assert per_gw >= float(t_n(jnp.int32(g_star), CFG)) - 1e-9 or \
            g_star == 1


def test_init_at_maximum():
    st0 = ControllerState.init(4, CFG)
    np.testing.assert_array_equal(np.asarray(st0.g), [4, 4, 4, 4])


def test_epoch_step_records():
    st0 = ControllerState.init(2, CFG)
    packets = jnp.asarray([40000.0, 100.0])   # heavy / light chiplet
    st1, rec = epoch_step(st0, packets, 1e6, CFG)
    assert int(rec["gt"]) == int(jnp.sum(st1.g))
    assert int(st1.epoch) == 1
    # light chiplet decreases from 4 (load < T_N_4)
    assert int(st1.g[1]) == 3
