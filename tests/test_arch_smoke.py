"""Per-architecture smoke tests (assignment requirement: reduced config of
the same family, one forward/train step on CPU, shape + finiteness)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_NAMES, get_smoke_config
from repro.models import get_model
from repro.models.params import count_params, init_params


def _batch(cfg, b=2, s=32, key=None):
    key = key or jax.random.PRNGKey(1)
    batch = {"tokens": jax.random.randint(key, (b, s), 0, cfg.real_vocab),
             "labels": jax.random.randint(key, (b, s), 0, cfg.real_vocab)}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(key, (b, s, cfg.d_model),
                                            jnp.float32)
    if cfg.family == "vlm":
        batch["image_embeds"] = jax.random.normal(
            key, (b, cfg.frontend_embeds, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_train_step_smoke(arch):
    cfg = get_smoke_config(arch)
    model = get_model(cfg)
    params = init_params(model.spec(), jax.random.PRNGKey(0))
    loss, stats = jax.jit(model.train_loss)(params, _batch(cfg))
    assert loss.shape == ()
    assert jnp.isfinite(loss), (arch, loss)
    assert float(loss) > 0


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_decode_smoke(arch):
    cfg = get_smoke_config(arch)
    model = get_model(cfg)
    params = init_params(model.spec(), jax.random.PRNGKey(0))
    b, s, max_len = 2, 16, 32
    batch = _batch(cfg, b, s)
    batch.pop("labels")
    caches, logits = jax.jit(
        lambda p, bt: model.prefill(p, bt, max_len))(params, batch)
    assert logits.shape == (b, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    tok = jnp.argmax(logits, -1)[:, None]
    logits2, _ = jax.jit(model.decode_step)(params, tok, caches)
    assert logits2.shape == (b, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits2)))


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_full_config_param_counts(arch):
    """Full (non-smoke) configs must be in the advertised size class."""
    from repro.configs import get_config
    cfg = get_config(arch)
    n = cfg.param_count()
    expected = {
        "mamba2-130m": (0.08e9, 0.3e9),
        "seamless-m4t-large-v2": (0.9e9, 3.5e9),  # frontend stubbed
        "stablelm-3b": (2e9, 4.5e9),
        "phi4-mini-3.8b": (2.5e9, 5.5e9),
        "command-r-plus-104b": (85e9, 125e9),
        "starcoder2-7b": (5e9, 9e9),
        "grok-1-314b": (250e9, 380e9),
        "kimi-k2-1t-a32b": (0.85e12, 1.25e12),
        "pixtral-12b": (9e9, 15e9),
        "zamba2-7b": (5e9, 10e9),
    }[arch]
    assert expected[0] <= n <= expected[1], (arch, n)


def test_moe_active_params_below_total():
    from repro.configs import get_config
    cfg = get_config("kimi-k2-1t-a32b")
    assert cfg.active_param_count() < 0.1 * cfg.param_count()
