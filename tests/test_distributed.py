"""Fleet-layer tests: partitioning math, GridSharding invariants, the
sharded sweep surfaces, and the fleet launcher's deterministic grid.

The multi-DEVICE compiled path (pad + NamedSharding + gather parity) runs
in a subprocess with a forced 4-device host platform — XLA_FLAGS must be
set before jax initializes, which the in-process suite cannot do. The
multi-PROCESS path (real jax.distributed + gloo) is covered by
`benchmarks/smoke.py::distributed_smoke` (make verify) and
benchmarks/bench_distributed.py.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
from pathlib import Path

import jax
import numpy as np
import pytest

from repro.core import traffic
from repro.core.distributed import (GridSharding, init_distributed,
                                    is_distributed, partition_bounds)
from repro.core.simulator import (Arch, SimConfig, shard_sweep,
                                  sweep_workload)
from repro.launch import fleet

REPO = Path(__file__).resolve().parent.parent


def _sim() -> SimConfig:
    return SimConfig().with_arch(Arch.RESIPI)


# ---------------------------------------------------------------------------
# partition_bounds: the emulated-host contract
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("k", [1, 2, 5, 8, 13, 64])
@pytest.mark.parametrize("n", [1, 2, 3, 4, 7])
def test_partition_bounds_disjoint_cover(k, n):
    covered = []
    for i in range(n):
        start, stop = partition_bounds(k, n, i)
        assert 0 <= start <= stop <= k
        covered.extend(range(start, stop))
    assert covered == list(range(k))


def test_partition_bounds_matches_padded_block_layout():
    # 13 points on 4 shards pad to 16 -> blocks of 4; the pad lands in the
    # last block (exactly how a 1-D NamedSharding lays out the padded axis).
    assert [partition_bounds(13, 4, i) for i in range(4)] == \
        [(0, 4), (4, 8), (8, 12), (12, 13)]


def test_partition_bounds_rejects_out_of_range_shard():
    with pytest.raises(ValueError):
        partition_bounds(8, 2, 2)


# ---------------------------------------------------------------------------
# init_distributed: single-process fallback
# ---------------------------------------------------------------------------

def test_init_distributed_single_process_is_noop_and_idempotent():
    info = init_distributed()
    assert info["distributed"] is False
    assert info["num_processes"] == 1 and info["process_id"] == 0
    assert not is_distributed()
    assert init_distributed() == info      # second call: same answer


# ---------------------------------------------------------------------------
# GridSharding: single-device passthrough invariants
# ---------------------------------------------------------------------------

def test_grid_sharding_single_device_is_passthrough():
    gs = GridSharding(5)
    assert gs.describe() == {"grid_points": 5, "pad_lanes": 0,
                             "devices": 1, "processes": 1}
    x = np.arange(10.0).reshape(5, 2)
    sharded = gs.shard(x)
    np.testing.assert_array_equal(np.asarray(sharded), x)
    # replicate is IDENTITY on single-process meshes (the warm-cache
    # behaviour every existing test pins must not change)
    obj = {"a": x, "b": None}
    assert gs.replicate(obj) is obj
    np.testing.assert_array_equal(np.asarray(gs.gather(sharded)), x)


def test_grid_sharding_rejects_empty_devices():
    with pytest.raises(ValueError):
        GridSharding(4, devices=[])


def test_grid_sharding_pad_tree_repeats_last_row():
    gs = GridSharding(3)
    gs.pad = 2                     # exercise the pad path on one device
    x = np.arange(6.0).reshape(3, 2)
    padded = np.asarray(gs.pad_tree(x))
    assert padded.shape == (5, 2)
    np.testing.assert_array_equal(padded[3], x[-1])
    np.testing.assert_array_equal(padded[4], x[-1])
    # gather slices the pad back off
    np.testing.assert_array_equal(np.asarray(gs.gather(padded)), x)


# ---------------------------------------------------------------------------
# Sharded sweep surfaces (single-device: metadata + unchanged numerics)
# ---------------------------------------------------------------------------

def test_shard_sweep_reports_sharding_metadata():
    sim = _sim()
    tr = traffic.generate(traffic.UniformSpec(n_intervals=6),
                          jax.random.PRNGKey(0),
                          sim.cfg.with_topology(n_chiplets=9))
    out = shard_sweep([tr], sim, n_chiplets=[4, 9])
    assert out["summary"]["pad_lanes"] == 0
    assert out["sharding"] == {"grid_points": 2, "pad_lanes": 0,
                               "devices": 1, "processes": 1}


def test_sweep_workload_devices_none_is_unchanged():
    sim = _sim()
    specs = [traffic.UniformSpec(n_intervals=6),
             traffic.BurstySpec(n_intervals=6)]
    a = sweep_workload(specs, sim, n_chiplets=[4, 9])
    b = sweep_workload(specs, sim, n_chiplets=[4, 9], devices=None)
    np.testing.assert_array_equal(
        np.asarray(a["summary"]["mean_latency"]),
        np.asarray(b["summary"]["mean_latency"]))
    assert "sharding" not in a


def test_sweep_workload_gen_chiplets_validation():
    sim = _sim()
    specs = [traffic.UniformSpec(n_intervals=6)]
    with pytest.raises(ValueError, match="gen_chiplets"):
        sweep_workload(specs, sim, n_chiplets=[16], gen_chiplets=9)


def test_sweep_workload_gen_chiplets_pins_trace_generation():
    # A shard whose slice misses the global max chiplet count still
    # reproduces the full run's rows when gen_chiplets + keys are pinned.
    sim = _sim()
    specs = [traffic.UniformSpec(n_intervals=6),
             traffic.BurstySpec(n_intervals=6),
             traffic.UniformSpec(n_intervals=6),
             traffic.BurstySpec(n_intervals=6)]
    cs = [4, 4, 16, 16]
    keys = jax.random.split(jax.random.PRNGKey(0), 4)
    full = sweep_workload(specs, sim, keys=keys, n_chiplets=cs)
    half = sweep_workload(specs[:2], sim, keys=keys[:2], n_chiplets=cs[:2],
                          gen_chiplets=16)
    np.testing.assert_allclose(
        np.asarray(half["summary"]["mean_latency"]),
        np.asarray(full["summary"]["mean_latency"])[:2], rtol=1e-6)


# ---------------------------------------------------------------------------
# Fleet launcher: deterministic grid construction
# ---------------------------------------------------------------------------

def test_fleet_grid_is_deterministic_and_complete():
    cfg = _sim().cfg
    a = fleet.build_grid(cfg, chiplets=[4, 9], placements=3,
                         workloads=["uniform", "bursty"], intervals=6,
                         seed=7)
    b = fleet.build_grid(cfg, chiplets=[4, 9], placements=3,
                         workloads=["uniform", "bursty"], intervals=6,
                         seed=7)
    assert a["k"] == 2 * 3 * 2
    assert a["labels"] == b["labels"]
    assert a["grids"]["gateway_positions"] == b["grids"]["gateway_positions"]
    c = fleet.build_grid(cfg, chiplets=[4, 9], placements=3,
                         workloads=["uniform", "bursty"], intervals=6,
                         seed=8)
    assert a["grids"]["gateway_positions"] != c["grids"]["gateway_positions"]


def test_fleet_sample_placements_on_border():
    cfg = _sim().cfg
    ps = fleet.sample_placements(cfg, 4, seed=0)
    assert len(ps) == 4 and ps[0] is None
    r = cfg.mesh_x
    for p in ps[1:]:
        assert len(p) == cfg.max_gateways_per_chiplet
        assert len(set(p)) == len(p)
        for (x, y) in p:
            assert x in (0, r - 1) or y in (0, r - 1)


def test_fleet_slice_grid_concatenates_to_full():
    cfg = _sim().cfg
    grid = fleet.build_grid(cfg, chiplets=[4, 9], placements=2,
                            workloads=["uniform"], intervals=6, seed=0)
    parts = [fleet.slice_grid(grid, *partition_bounds(grid["k"], 3, i))
             for i in range(3)]
    assert sum(p["k"] for p in parts) == grid["k"]
    assert [l for p in parts for l in p["labels"]] == grid["labels"]


# ---------------------------------------------------------------------------
# Multi-device compiled path (forced 4-device host platform, subprocess)
# ---------------------------------------------------------------------------

_SHARDED_CHILD = r"""
import json, sys
import jax, numpy as np
from repro.core import traffic
from repro.core.simulator import Arch, SimConfig, sweep_workload
assert len(jax.devices()) == 4
sim = SimConfig().with_arch(Arch.RESIPI)
specs = [traffic.UniformSpec(n_intervals=6),
         traffic.BurstySpec(n_intervals=6),
         traffic.UniformSpec(n_intervals=6)]
import warnings
with warnings.catch_warnings():
    warnings.simplefilter("error")    # sharded fallback warning = failure
    a = sweep_workload(specs, sim, n_chiplets=[4, 9, 16],
                       devices=jax.devices())
b = sweep_workload(specs, sim, n_chiplets=[4, 9, 16])
la = np.asarray(a["summary"]["mean_latency"], np.float64)
lb = np.asarray(b["summary"]["mean_latency"], np.float64)
print("RESULT " + json.dumps({
    "parity": bool(np.allclose(la, lb, atol=1e-6)),
    "shape_ok": la.shape == (3,),
    "pad_lanes": int(a["summary"]["pad_lanes"]),
    "sharding": a["sharding"]}))
"""


def test_sharded_sweep_multi_device_parity_and_pad():
    env = dict(os.environ,
               PYTHONPATH=str(REPO / "src"),
               XLA_FLAGS=(os.environ.get("XLA_FLAGS", "") +
                          " --xla_force_host_platform_device_count=4")
               .strip())
    proc = subprocess.run([sys.executable, "-c", _SHARDED_CHILD], cwd=REPO,
                          env=env, timeout=600, capture_output=True,
                          text=True)
    assert proc.returncode == 0, f"{proc.stdout}\n{proc.stderr}"
    line = [l for l in proc.stdout.splitlines()
            if l.startswith("RESULT ")][0]
    r = json.loads(line[len("RESULT "):])
    assert r["parity"] and r["shape_ok"]
    # 3 grid points on 4 devices: ONE padded lane, reported, never silent
    assert r["pad_lanes"] == 1
    assert r["sharding"]["devices"] == 4 and r["sharding"]["processes"] == 1
