"""Continuous-batching session-server example: a bursty multi-tenant mix
over shared lanes, with a deadline and an overload burst that sheds.

    PYTHONPATH=src python examples/serve_batch.py
"""
from repro.launch.serve import main as serve_main


def main():
    serve_main(["--ticks", "16", "--lanes", "4", "--chunk", "8",
                "--queue-capacity", "8", "--arrival-rate", "1.5",
                "--burst-at", "4", "--burst-size", "10",
                "--deadline", "12"])


if __name__ == "__main__":
    main()
