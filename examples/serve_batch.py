"""Batched serving example: prefill + lockstep decode with a KV cache on a
GQA model (phi4-mini family, smoke scale).

    PYTHONPATH=src python examples/serve_batch.py
"""
from repro.launch.serve import main as serve_main


def main():
    serve_main(["--arch", "phi4-mini-3.8b", "--smoke",
                "--requests", "8", "--batch", "4",
                "--prompt-len", "24", "--new-tokens", "12",
                "--max-len", "64"])


if __name__ == "__main__":
    main()
