"""End-to-end training example: a ~130M-param Mamba2 for a few hundred
steps on CPU-runnable shapes, with checkpoint/restart, the in-step NaN
guard, and the ReSiPI lane controller live.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]

This drives the same launcher a cluster run uses (repro.launch.train); on a
TPU pod you would drop --smoke and point --arch at any of the ten assigned
architectures with the production mesh.
"""
import argparse
import sys

from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--arch", default="mamba2-130m")
    args = ap.parse_args()
    losses = train_main([
        "--arch", args.arch, "--smoke",
        "--steps", str(args.steps),
        "--batch", "8", "--seq", "256",
        "--lr", "3e-3",
        "--ckpt-dir", "/tmp/repro_train_lm",
        "--ckpt-every", "100",
        "--epoch-steps", "25",
        "--log-every", "25",
        "--resume",
    ])
    first, last = losses[0], sum(losses[-10:]) / 10
    print(f"\nloss {first:.3f} -> {last:.3f} "
          f"({'OK: learning' if last < first - 0.3 else 'WARN: check'})")


if __name__ == "__main__":
    main()
