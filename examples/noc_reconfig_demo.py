"""ReSiPI reconfiguration walkthrough: watch the controller + PCMCs react
to a live application switch (the Fig. 12 experiment, narrated), then scale
the same engine to a hundreds-of-chiplets topology scan in ONE compiled
executable (the HexaMesh/PlaceIT-style DSE the padded sweep engine enables),
and finally let `search_placement` redesign the gateway floorplan itself.

    PYTHONPATH=src python examples/noc_reconfig_demo.py

All sections ride the compile-once engine API: `simulate` jit-caches on
(trace shape, config), `sweep_topology`/`sweep_placement` pad every grid
point to the maxima so a whole grid shares one executable, and the search
loop reuses that one executable for every generation — the printed
`engine_stats()` lines show the scan-body trace counts staying put.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import photonics, traffic
from repro.core.constants import NETWORK
from repro.core.simulator import (Arch, SimConfig, engine_stats,
                                  reset_engine_stats, search_placement,
                                  simulate, sweep_topology)


def reconfiguration_walkthrough():
    seq = ["blackscholes", "facesim", "dedup"]
    keys = jax.random.split(jax.random.PRNGKey(0), 3)
    tr = traffic.concat_traces([
        traffic.generate_trace(app, 30, k) for app, k in zip(seq, keys)])
    out = simulate(tr, SimConfig().with_arch(Arch.RESIPI))
    recs = out["records"]
    g = np.asarray(recs["g"])
    power = np.asarray(recs["power_mw"])
    lat = np.asarray(recs["latency"])
    gmax = NETWORK.max_gateways_per_chiplet

    print("interval | app          | GT | latency | power_mW | kappa chain")
    for i in range(0, 90, 6):
        app = seq[i // 30]
        # gateway-chain activity mask (chiplet slots + memory gateways)
        slots = jnp.arange(gmax)[None, :] < jnp.asarray(g[i])[:, None]
        active = jnp.concatenate(
            [slots.reshape(-1), jnp.ones((NETWORK.memory_gateways,), bool)])
        kappa = photonics.kappa_schedule(active)
        k_str = ",".join(f"{float(k):.2f}" for k in np.asarray(kappa)[:5])
        print(f"{i:8d} | {app:12s} | "
              f"{int(g[i].sum()) + NETWORK.memory_gateways:2d} | "
              f"{lat[i]:7.2f} | {power[i]:8.1f} | [{k_str},...]")

    print("\nPCM reconfiguration energy total: "
          f"{float(np.sum(np.asarray(recs['reconfig_nj']))):.0f} nJ "
          "(zero while the activity pattern holds — non-volatile)")
    print(f"engine: {engine_stats()['simulate_traces']} scan-body trace(s) "
          "for the walkthrough (compile-once, repeat calls are free)")


def hundreds_of_chiplets_scan():
    """16 -> 256 chiplets, one padded executable for the whole scan."""
    counts = [16, 36, 64, 100, 144, 196, 256]
    cfg = NETWORK.with_topology(n_chiplets=max(counts))
    tr = traffic.generate_trace("canneal", 16, jax.random.PRNGKey(1), cfg)

    before = engine_stats()["simulate_traces"]
    out = sweep_topology(tr, SimConfig().with_arch(Arch.RESIPI),
                         n_chiplets=counts)["summary"]
    traces = engine_stats()["simulate_traces"] - before

    print("\nhundreds-of-chiplets scan (ONE padded compiled executable):")
    print("chiplets | latency | power_mW | mean GT")
    for i, c in enumerate(counts):
        print(f"{c:8d} | {float(out['mean_latency'][i]):7.2f} | "
              f"{float(out['mean_power_mw'][i]):8.0f} | "
              f"{float(out['mean_gateways'][i]):7.1f}")
    print(f"engine: {traces} scan-body trace for {len(counts)} topologies "
          f"(padded to {max(counts)} chiplets, masked slots provably idle)")


def placement_search_walkthrough():
    """Redesign the gateway floorplan with the compiled placement search.

    `NetworkConfig.gateway_positions` makes gateway placement a first-class,
    sweepable axis: `search_placement` proposes candidate placements in
    numpy (single-gateway moves + random restarts, rows kept in controller
    activation order) and scores each generation with ONE `sweep_placement`
    call, so the entire search compiles exactly once. Interior placements
    trade shorter router->gateway walks against access-waveguide loss
    (photonics.gateway_access_loss_db) — the search surfaces that frontier.
    """
    tr = traffic.generate_trace("dedup", 24, jax.random.PRNGKey(2))
    before = engine_stats()["simulate_traces"]
    res = search_placement(tr, SimConfig().with_arch(Arch.RESIPI),
                           generations=8, population=12, seed=0)
    traces = engine_stats()["simulate_traces"] - before

    print("\nplacement search (Table 1 system, objective: inter-chiplet "
          "latency):")
    print("generation | incumbent | best-so-far | accepted")
    for h in res["history"]:
        print(f"{h['generation']:10d} | {h['parent_score']:9.3f} | "
              f"{h['best_score']:11.3f} | {h['accepted']}")
    print(f"default edge scheme {res['default_score']:.3f} -> best "
          f"{res['best_placement']} at {res['best_score']:.3f} "
          f"(inter-chiplet latency {-res['improvement_frac']:+.1%})")
    print(f"engine: {traces} scan-body trace for "
          f"{res['generations']} generations x {res['population']} "
          f"candidates (every generation reuses the one executable)")


def main():
    reset_engine_stats()
    reconfiguration_walkthrough()
    hundreds_of_chiplets_scan()
    placement_search_walkthrough()


if __name__ == "__main__":
    main()
