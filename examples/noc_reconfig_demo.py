"""ReSiPI reconfiguration walkthrough: watch the controller + PCMCs react
to a live application switch (the Fig. 12 experiment, narrated), then scale
the same engine to a hundreds-of-chiplets topology scan in ONE compiled
executable (the HexaMesh/PlaceIT-style DSE the padded sweep engine enables),
let the device-resident `search_placement` redesign the gateway floorplan
itself in a single dispatch (plus `search_placement_islands`: K annealed
chains x a runtime-knob grid in one executable), sweep a mixed PARSEC +
synthetic workload set of ragged lengths through one executable
(`sweep_workload`), stream an unbounded trace through a fixed-memory
`SimSession`, survive a fault storm: injected router failures detected
from session telemetry and healed by a live, blocked-search re-placement
with the PCM switching cost charged (`repro.core.faults` +
`repro.serve.resilience`), serve a multi-tenant session mix
through the continuous-batching `SessionServer` (admit -> overload shed ->
fault storm -> heal -> drain, all on one packed executable), and finally
resolve *destinations*: transpose/tornado vs uniform at the same mean load
separate into distinct latency/power frontier points once their
destination matrices ride along (`generate(..., dest=True)`), with the
fused `epoch_step` Pallas kernel reproducing the frontier at 1e-6 — then
close with the joint co-design search: the Pareto-optimal floorplan set
for a 256-chiplet system across 8 workloads, topology x placement x knob
in ONE dispatch (`repro.core.pareto.search_codesign`).

    PYTHONPATH=src python examples/noc_reconfig_demo.py

All sections ride the compile-once engine API: `simulate` jit-caches on
(trace shape, config), `sweep_topology`/`sweep_placement`/`sweep_workload`
pad every grid point to the maxima so a whole grid shares one executable,
and the search loop reuses that one executable for every generation — the
printed `engine_stats()` lines show the scan-body trace counts staying put.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import photonics, traffic
from repro.core.constants import NETWORK
from repro.core.simulator import (Arch, SimConfig, SimSession, engine_stats,
                                  reset_engine_stats, search_placement,
                                  simulate, sweep_topology, sweep_workload)


def reconfiguration_walkthrough():
    seq = ["blackscholes", "facesim", "dedup"]
    keys = jax.random.split(jax.random.PRNGKey(0), 3)
    tr = traffic.concat_traces([
        traffic.generate_trace(app, 30, k) for app, k in zip(seq, keys)])
    out = simulate(tr, SimConfig().with_arch(Arch.RESIPI))
    recs = out["records"]
    g = np.asarray(recs["g"])
    power = np.asarray(recs["power_mw"])
    lat = np.asarray(recs["latency"])
    gmax = NETWORK.max_gateways_per_chiplet

    print("interval | app          | GT | latency | power_mW | kappa chain")
    for i in range(0, 90, 6):
        app = seq[i // 30]
        # gateway-chain activity mask (chiplet slots + memory gateways)
        slots = jnp.arange(gmax)[None, :] < jnp.asarray(g[i])[:, None]
        active = jnp.concatenate(
            [slots.reshape(-1), jnp.ones((NETWORK.memory_gateways,), bool)])
        kappa = photonics.kappa_schedule(active)
        k_str = ",".join(f"{float(k):.2f}" for k in np.asarray(kappa)[:5])
        print(f"{i:8d} | {app:12s} | "
              f"{int(g[i].sum()) + NETWORK.memory_gateways:2d} | "
              f"{lat[i]:7.2f} | {power[i]:8.1f} | [{k_str},...]")

    print("\nPCM reconfiguration energy total: "
          f"{float(np.sum(np.asarray(recs['reconfig_nj']))):.0f} nJ "
          "(zero while the activity pattern holds — non-volatile)")
    print(f"engine: {engine_stats()['simulate_traces']} scan-body trace(s) "
          "for the walkthrough (compile-once, repeat calls are free)")


def hundreds_of_chiplets_scan():
    """16 -> 256 chiplets, one padded executable for the whole scan."""
    counts = [16, 36, 64, 100, 144, 196, 256]
    cfg = NETWORK.with_topology(n_chiplets=max(counts))
    tr = traffic.generate_trace("canneal", 16, jax.random.PRNGKey(1), cfg)

    before = engine_stats()["simulate_traces"]
    out = sweep_topology(tr, SimConfig().with_arch(Arch.RESIPI),
                         n_chiplets=counts)["summary"]
    traces = engine_stats()["simulate_traces"] - before

    print("\nhundreds-of-chiplets scan (ONE padded compiled executable):")
    print("chiplets | latency | power_mW | mean GT")
    for i, c in enumerate(counts):
        print(f"{c:8d} | {float(out['mean_latency'][i]):7.2f} | "
              f"{float(out['mean_power_mw'][i]):8.0f} | "
              f"{float(out['mean_gateways'][i]):7.1f}")
    print(f"engine: {traces} scan-body trace for {len(counts)} topologies "
          f"(padded to {max(counts)} chiplets, masked slots provably idle)")


def placement_search_walkthrough():
    """Redesign the gateway floorplan with the device-resident search.

    `NetworkConfig.gateway_positions` makes gateway placement a first-class,
    sweepable axis, and `search_placement` now runs the ENTIRE annealed
    search on device (repro.core.search): proposals (collision-free
    single-gateway moves + random restarts, spread-reordered by the
    traceable activation rule), candidate tables (the jnp twins of the
    selection builders), scoring, annealed acceptance and the history all
    live inside ONE `lax.scan` — a whole search is a single dispatch with
    zero host round-trips between generations. Interior placements trade
    shorter router->gateway walks against access-waveguide loss
    (photonics.gateway_access_loss_db) — the search surfaces that frontier.
    (`engine="host"` keeps the PR-3 numpy-proposal loop as a parity oracle.)
    """
    tr = traffic.generate_trace("dedup", 24, jax.random.PRNGKey(2))
    reset_engine_stats()
    res = search_placement(tr, SimConfig().with_arch(Arch.RESIPI),
                           generations=8, population=12, seed=0)
    stats = engine_stats()

    print("\nplacement search (Table 1 system, objective: inter-chiplet "
          "latency):")
    print("generation | incumbent | best-so-far | accepted")
    for h in res["history"]:
        print(f"{h['generation']:10d} | {h['parent_score']:9.3f} | "
              f"{h['best_score']:11.3f} | {h['accepted']}")
    print(f"default edge scheme {res['default_score']:.3f} -> best "
          f"{res['best_placement']} at {res['best_score']:.3f} "
          f"(inter-chiplet latency {-res['improvement_frac']:+.1%})")
    print(f"engine: {stats['simulate_traces']} scan-body trace, "
          f"{stats['search_dispatches']} dispatch for "
          f"{res['generations']} generations x {res['population']} "
          f"candidates (the whole search is one compiled lax.scan)")


def island_search_walkthrough():
    """K annealed chains + a runtime-knob grid in ONE compiled executable.

    `search_placement_islands` vmaps K independent search chains over seeds
    inside the same single-dispatch executable — embarrassingly parallel
    restarts at the cost of one — and runtime `SWEEPABLE_FIELDS` grids of
    length K zip with the island axis. Here each island searches the best
    floorplan for a different L_m operating point: a joint placement x
    controller-threshold exploration (the step toward the ROADMAP's joint
    search item). With more than one device the island axis shards via
    NamedSharding.
    """
    from repro.core.simulator import search_placement_islands

    tr = traffic.generate_trace("dedup", 24, jax.random.PRNGKey(3))
    lms = [0.008, 0.0152, 0.024, 0.032]
    reset_engine_stats()
    res = search_placement_islands(
        tr, SimConfig().with_arch(Arch.RESIPI),
        generations=8, population=12, seed=0, l_m=lms)
    stats = engine_stats()

    print("\nisland search: best placement per L_m operating point "
          "(4 chains, ONE dispatch):")
    print("island |    L_m | default | best    | found placement")
    for k in range(res["islands"]):
        print(f"{k:6d} | {lms[k]:6.4f} | "
              f"{res['island_default_scores'][k]:7.3f} | "
              f"{res['island_best_scores'][k]:7.3f} | "
              f"{res['island_best_placements'][k]}")
    print(f"overall best: island {res['best_island']} at "
          f"{res['best_score']:.3f} ({-res['improvement_frac']:+.1%} vs its "
          f"default)")
    print(f"engine: {stats['simulate_traces']} scan-body trace, "
          f"{stats['search_dispatches']} dispatch for "
          f"{res['islands']} islands x {res['generations']} generations x "
          f"{res['population']} candidates")


def mixed_workload_sweep():
    """Workloads are a sweep axis too: apps + synthetics, ragged lengths.

    `traffic.TrafficSpec`s are frozen/hashable, so a whole workload set —
    calibrated PARSEC apps next to canonical synthetic NoC patterns, each
    with its own trace length — generates under jit from one seed and runs
    as ONE compiled executable: mixed lengths pad to the longest T under a
    `t_mask`, and masked tail intervals contribute exactly zero to every
    latency/power/energy reduction.
    """
    specs = [traffic.ParsecSpec(app="blackscholes", n_intervals=30),
             traffic.ParsecSpec(app="facesim", n_intervals=18),
             traffic.UniformSpec(n_intervals=24),
             traffic.HotspotSpec(n_hotspots=1, n_intervals=24),
             traffic.PermutationSpec(pattern="transpose", n_intervals=20),
             traffic.BurstySpec(n_intervals=28)]
    before = engine_stats()["simulate_traces"]
    out = sweep_workload(specs, SimConfig().with_arch(Arch.RESIPI), seed=0)
    traces = engine_stats()["simulate_traces"] - before

    print("\nmixed-workload ragged-length sweep (ONE padded executable):")
    print("workload     |  T | latency | power_mW | mean GT | saturated")
    for i, s in enumerate(specs):
        print(f"{s.name:12s} | {s.n_intervals:2d} | "
              f"{float(out['summary']['mean_latency'][i]):7.2f} | "
              f"{float(out['summary']['mean_power_mw'][i]):8.0f} | "
              f"{float(out['summary']['mean_gateways'][i]):7.1f} | "
              f"{float(out['summary']['saturated_frac'][i]):9.2f}")
    print(f"engine: {traces} scan-body trace for {len(specs)} workloads "
          f"(T=18..30 padded to 30, masked tails provably zero)")


def streaming_session_walkthrough():
    """Unbounded online traces at fixed memory: SimSession.

    The controller state carries across chunks (the carry is donated, so
    steady streaming reuses its buffers), every equal-length chunk hits one
    compiled executable, and the chunked records bit-match the one-shot
    `simulate` of the concatenated trace.
    """
    base = SimConfig().with_arch(Arch.RESIPI)
    apps = ["blackscholes", "facesim", "dedup"]
    keys = jax.random.split(jax.random.PRNGKey(4), 3)
    full = traffic.concat_traces([
        traffic.generate_trace(a, 20, k) for a, k in zip(apps, keys)])

    before = engine_stats()["simulate_traces"]
    session = SimSession.init(base)
    print("\nstreaming session (chunks of 10, state persists across "
          "chunks):")
    print("chunk | intervals seen | chunk latency | running latency")
    for i, chunk in enumerate(traffic.chunk_trace(full, 10)):
        out = session.step_chunk(chunk)
        print(f"{i:5d} | {session.intervals_seen:14d} | "
              f"{float(out['summary']['mean_latency']):13.2f} | "
              f"{float(session.summary()['mean_latency']):15.2f}")
    traces = engine_stats()["simulate_traces"] - before

    one = simulate(full, base)
    drift = abs(float(session.summary()["mean_latency"])
                - float(one["summary"]["mean_latency"]))
    print(f"engine: {traces} scan-body trace for 6 chunks (equal shapes "
          f"share one executable); chunked-vs-oneshot mean latency drift "
          f"{drift:.2e}")


def fault_storm_recovery_walkthrough():
    """Closed-loop self-healing: a fault storm, detected and survived.

    At interval 32 a storm kills the interposer routers under half the
    live gateways (`faults.GatewayFault` targeted by *position*, so the
    failure follows the routers, not the logical slots). The
    `ResilienceRuntime` watches the streaming session's per-chunk
    telemetry: two consecutive chunks over the 10% latency band trigger a
    warm-restarted device `search_placement` with the dead routers —
    reported by the injector's hardware status register — masked out of
    the proposal space. The recovered placement swaps in live
    (zero-recompile: placement reaches the executable only through traced
    tables) and the PCM switching energy + reconfiguration stall are
    charged to the runtime's bill.
    """
    import dataclasses

    from repro.core import faults
    from repro.core.gateway_controller import ControllerConfig
    from repro.serve.resilience import ResiliencePolicy, ResilienceRuntime

    # Pin the controller at 4 gateways and double the load so a dead
    # router is a real capacity loss (the adaptive controller at light
    # load simply activates spare slots — resilient, but undramatic).
    base = SimConfig().with_arch(Arch.RESIPI)
    sim = dataclasses.replace(base, ctl=ControllerConfig(
        l_m=base.ctl.l_m, max_gateways=4, min_gateways=4))
    tr = traffic.generate_trace("dedup", 64, jax.random.PRNGKey(0))
    for k in ("ext_load", "mem_load", "int_load"):
        tr[k] = jnp.asarray(tr[k]) * 2.0

    runtime = ResilienceRuntime(
        SimSession.init(sim),
        ResiliencePolicy(threshold_frac=0.10, hysteresis=2, cooldown=1))
    victims = runtime.session.placement[:2]
    injector = faults.FaultInjector(
        [faults.GatewayFault(start=32, position=p) for p in victims], 64)

    print("\nfault-storm recovery (routers "
          f"{victims[0]}/{victims[1]} die at interval 32):")
    print("chunk | latency | baseline | breach | action")
    for i, chunk in enumerate(traffic.chunk_trace(tr, 8)):
        t0 = i * 8
        faulted = injector.inject(chunk, runtime.current_cfg, t0)
        runtime.report_failed_positions(injector.failed_positions(t0))
        out = runtime.observe(faulted)
        action = "-"
        if out["healed"] is not None:
            h = out["healed"]
            action = (f"HEAL: moved {h['moved_gateways']} gateways off "
                      f"{list(h['blocked_positions'])} "
                      f"({h['pcm_nj']:.0f} nJ PCM)")
        elif out["breach"]:
            action = "breach (hysteresis holding)"
        print(f"{i:5d} | {out['latency']:7.2f} | {out['baseline']:8.2f} | "
              f"{str(out['breach']):6s} | {action}")
    print(f"recovered placement: {runtime.session.placement}")
    print(f"bill: {runtime.total_pcm_nj:.0f} nJ PCM, "
          f"{runtime.total_stall_cycles} stall cycles, "
          f"{runtime.replacements} re-placement(s) — the post-heal chunks "
          f"run within 10% of the pre-fault baseline")


def session_server_walkthrough():
    """The serving layer end to end: admit -> overload shed -> fault storm
    -> heal -> drain.

    A `SessionServer` packs every resident session's next padded chunk
    into ONE `[lanes, chunk]` executable per tick (t_mask freeze semantics
    make empty lanes and ragged tails exact, so lane k bit-matches a
    standalone `SimSession`). Around that ride the robustness knobs: a
    bounded admission queue that sheds a burst by priority, a mid-serve
    router fault storm detected from packed-lane telemetry and healed by
    a blocked re-placement swapped into every lane at once, and a clean
    drain — zero healthy sessions dropped end to end.
    """
    import dataclasses

    from repro.core import faults
    from repro.core.gateway_controller import ControllerConfig
    from repro.serve.engine import SessionServer, replay_standalone
    from repro.serve.policies import (PRIORITY_BATCH, PRIORITY_PREMIUM,
                                      ServerPolicy)
    from repro.serve.resilience import ResiliencePolicy
    from repro.serve.scheduler import SessionRequest

    # Same pinned-g4 / x2-load calibration as the storm walkthrough above.
    base = SimConfig().with_arch(Arch.RESIPI)
    sim = dataclasses.replace(base, ctl=ControllerConfig(
        l_m=base.ctl.l_m, max_gateways=4, min_gateways=4))

    def stream(seed, t):
        tr = traffic.generate_trace("dedup", t, jax.random.PRNGKey(seed))
        for k in ("ext_load", "mem_load", "int_load"):
            tr[k] = jnp.asarray(tr[k]) * 2.0
        return tr

    policy = ServerPolicy(lanes=2, chunk_intervals=8, queue_capacity=3)
    victims = SessionServer(sim, policy).placement[:2]
    env = faults.FaultInjector(
        [faults.GatewayFault(start=32, position=p) for p in victims], 256)
    server = SessionServer(
        sim, policy, fault_env=env,
        resilience=ResiliencePolicy(threshold_frac=0.10, hysteresis=2,
                                    cooldown=1, search_generations=4,
                                    search_population=6))

    print("\nsession server (2 lanes, queue capacity 3, routers "
          f"{victims[0]}/{victims[1]} die at hardware interval 32):")
    # Admit: two long streams fill the lanes (one tick admits them), two
    # more queue up behind.
    for i in range(2):
        out = server.submit(SessionRequest(trace=stream(i, 64)))
        print(f"  submit s{i}: {out['signal']}")
    server.run(1)
    for i in range(2, 4):
        out = server.submit(SessionRequest(trace=stream(i, 64)))
        print(f"  submit s{i}: {out['signal']}")
    # Overload: a burst past capacity — premium displaces queued batch
    # work, the rest sheds at the door with a taxonomy reason.
    print("  -- burst --")
    for i, pr in enumerate([PRIORITY_BATCH, PRIORITY_PREMIUM,
                            PRIORITY_BATCH, PRIORITY_BATCH]):
        out = server.submit(SessionRequest(trace=stream(10 + i, 16),
                                           priority=pr))
        print(f"  submit burst[{i}] (priority {pr}): {out['signal']}"
              + (f" ({out['reason']})" if out["reason"] else ""))

    server.drain()
    print("tick | in-flight | queue | deg | latency | breach | action")
    for e in server.events:
        lat = "      -" if e["latency"] is None else f"{e['latency']:7.2f}"
        action = "-"
        if e.get("healed"):
            h = e["healed"]
            if h["moved_gateways"]:
                action = (f"HEAL: moved {h['moved_gateways']} gateways off "
                          f"{list(h['blocked_positions'])} "
                          f"({h['pcm_nj']:.0f} nJ PCM)")
            else:
                action = ("re-search: incumbent confirmed (capacity loss "
                          "is real; 0 nJ)")
        elif e["breach"]:
            action = "breach (hysteresis holding)"
        deg = "  *" if e["degraded"] else "   "    # coalesced double-chunks
        print(f"{e['tick']:4d} | {e['in_flight']:9d} | "
              f"{e['queue_depth']:5d} | {deg} | {lat} | "
              f"{str(e['breach']):6s} | {action}")

    m = server.metrics()
    sess = server.completed[0]
    parity = all(
        float(replay_standalone(sim, sess)[k]) == sess.summary()[k]
        for k in ("mean_latency", "mean_energy", "valid_intervals"))
    print(f"drained: {m['completed']}/{m['admitted']} admitted sessions "
          f"completed ({m['shed_queue_full'] + m['shed_priority']} shed, "
          f"{m['displaced']} displaced), {m['heals']} heal(s), "
          f"bill {m['total_pcm_nj']:.0f} nJ PCM")
    print(f"replay parity: lane-packed {sess.id} bit-matches its "
          f"standalone SimSession replay = {parity}")


def destination_fidelity_walkthrough():
    """Destination-aware routing: transpose/tornado vs uniform at the SAME
    calibrated mean load, with and without their destination matrices.

    Destination-blind, the engine sees only injected load columns, so
    these patterns differ just by sampling noise. `generate(...,
    dest=True)` attaches the spec's row-stochastic destination matrix and
    the engine resolves actual source->destination gateway pressure — the
    permutation workloads separate into their own latency/power frontier
    points (transpose's self-paired chiplets divert to intra traffic, so
    its power collapses too). The fused `epoch_step` Pallas kernel
    (`SimConfig.epoch_kernel=True`) reproduces the scan body on the same
    traces at 1e-6 — same frontier, one kernel launch per trace.
    """
    import dataclasses

    sim = SimConfig()
    sim_k = dataclasses.replace(sim, epoch_kernel=True)
    specs = [("uniform", traffic.UniformSpec(mean_load=0.05,
                                             n_intervals=48)),
             ("transpose", traffic.PermutationSpec(
                 pattern="transpose", mean_load=0.05, n_intervals=48)),
             ("tornado", traffic.PermutationSpec(
                 pattern="tornado", mean_load=0.05, n_intervals=48))]

    def inter_latency(trace, cfg):
        out = simulate(trace, cfg)
        tm = np.asarray(trace.get("t_mask",
                                  np.ones(np.shape(trace["mem_load"]))))
        return (float(np.asarray(out["records"]["mean_inter_latency"])
                      .sum() / tm.sum()),
                float(out["summary"]["mean_power_mw"]))

    print("\ndestination-aware frontier (mean_load=0.05 for every "
          "pattern):")
    print("pattern    | blind lat | dest lat | dest power | kernel lat")
    for name, spec in specs:
        tr = traffic.generate(spec, jax.random.PRNGKey(0), dest=True)
        blind, _ = inter_latency({k: v for k, v in tr.items()
                                  if k != "dest"}, sim)
        lat, pw = inter_latency(tr, sim)
        lat_k, _ = inter_latency(tr, sim_k)
        print(f"{name:10s} | {blind:9.2f} | {lat:8.2f} | {pw:10.0f} | "
              f"{lat_k:10.2f}")
    print("destination matrices move each pattern off the blind numbers, "
          "and the fused kernel lands on the scan body's exact frontier")


def pareto_codesign_walkthrough():
    """The ROADMAP deliverable, verbatim: "give me the Pareto-optimal
    floorplan set for a 256-chiplet system across 8 workloads" as ONE
    dispatch.

    `pareto.search_codesign` scans a padded topology grid up to 256
    chiplets with an outer `lax.scan`, runs K annealed island chains per
    point (each under its own Das-Dennis scalarization weight and its own
    L_m operating point, exchanging incumbents on a ring every few
    generations), scores every candidate on all 8 PARSEC workloads at
    once, and keeps a device-resident Pareto archive over
    (latency, power, energy). The front below — topology + placement +
    knob per point — comes back from a single compiled dispatch;
    `rescore_front_host` re-simulates each entry unpadded and matches at
    1e-6 (the oracle gate `make verify` runs).
    """
    from repro.core import pareto

    base = SimConfig().with_arch(Arch.RESIPI)
    counts = [64, 144, 256]
    apps = ["blackscholes", "swaptions", "streamcluster", "facesim",
            "fluidanimate", "bodytrack", "canneal", "dedup"]
    cfg = base.cfg.with_topology(n_chiplets=max(counts))
    traces = [traffic.generate_trace(a, 12, k, cfg) for a, k in
              zip(apps, jax.random.split(jax.random.PRNGKey(5), len(apps)))]

    reset_engine_stats()
    res = pareto.search_codesign(
        traces, base, n_chiplets=counts, islands=4, generations=6,
        population=6, archive=24, migrate_every=3,
        knob_grids={"l_m": [0.008, 0.0152, 0.024, 0.032]}, seed=0)
    stats = engine_stats()

    print("\nPareto co-design: 256-chiplet x 8-workload frontier "
          "(ONE dispatch):")
    print("chiplets |   L_m  | latency | power_mW |   energy | placement")
    shown = 0
    for e in res["front"]:
        if shown >= 8:
            break
        o = e["objectives"]
        print(f"{e['topology']['n_chiplets']:8d} | "
              f"{e['knobs']['l_m']:6.4f} | {o['latency']:7.2f} | "
              f"{o['power_mw']:8.0f} | {o['energy']:8.3g} | "
              f"{e['placement']}")
        shown += 1
    if len(res["front"]) > shown:
        print(f"  ... {len(res['front']) - shown} more front points")
    front = np.asarray([[e["objectives"][k] for k in
                         ("latency", "power_mw", "energy")]
                        for e in res["front"]])
    hv = pareto.hypervolume(front, tuple(2.0 * front.max(axis=0)))
    print(f"front: {len(res['front'])} non-dominated (topology, placement, "
          f"knob) points over {res['candidate_evals']} candidate evals "
          f"({len(counts)} topologies x 4 islands x 6x6 x {len(apps)} "
          f"workloads); hypervolume {hv:.3g}")
    print(f"engine: {stats['simulate_traces']} scan-body trace, "
          f"{stats['search_dispatches']} dispatch — the whole joint search "
          f"is one compiled executable, the front the only transfer")


def main():
    reset_engine_stats()
    reconfiguration_walkthrough()
    hundreds_of_chiplets_scan()
    placement_search_walkthrough()
    island_search_walkthrough()
    mixed_workload_sweep()
    streaming_session_walkthrough()
    fault_storm_recovery_walkthrough()
    session_server_walkthrough()
    destination_fidelity_walkthrough()
    pareto_codesign_walkthrough()


if __name__ == "__main__":
    main()
