"""ReSiPI reconfiguration walkthrough: watch the controller + PCMCs react
to a live application switch (the Fig. 12 experiment, narrated).

    PYTHONPATH=src python examples/noc_reconfig_demo.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import photonics, traffic
from repro.core.simulator import Arch, SimConfig, simulate


def main():
    seq = ["blackscholes", "facesim", "dedup"]
    keys = jax.random.split(jax.random.PRNGKey(0), 3)
    tr = traffic.concat_traces([
        traffic.generate_trace(app, 30, k) for app, k in zip(seq, keys)])
    out = simulate(tr, SimConfig().with_arch(Arch.RESIPI))
    recs = out["records"]
    g = np.asarray(recs["g"])
    power = np.asarray(recs["power_mw"])
    lat = np.asarray(recs["latency"])

    print("interval | app          | GT | latency | power_mW | kappa chain")
    for i in range(0, 90, 6):
        app = seq[i // 30]
        active = jnp.concatenate(
            [jnp.arange(4)[None, :] < jnp.asarray(g[i])[:, None],
             ], axis=0).reshape(-1)
        active = jnp.concatenate([active, jnp.ones((2,), bool)])
        kappa = photonics.kappa_schedule(active)
        k_str = ",".join(f"{float(k):.2f}" for k in np.asarray(kappa)[:5])
        print(f"{i:8d} | {app:12s} | {int(g[i].sum())+2:2d} | "
              f"{lat[i]:7.2f} | {power[i]:8.1f} | [{k_str},...]")

    print("\nPCM reconfiguration energy total: "
          f"{float(np.sum(np.asarray(recs['reconfig_nj']))):.0f} nJ "
          "(zero while the activity pattern holds — non-volatile)")


if __name__ == "__main__":
    main()
