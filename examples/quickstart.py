"""Quickstart: the ReSiPI paper pipeline end-to-end in ~30 lines.

Generates PARSEC-like traffic, simulates all four interposer architectures,
prints the paper's Fig. 11 headline comparison, then shows the same
controller managing communication lanes for a (smoke-scale) training step.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import traffic
from repro.core.simulator import simulate_all_archs
from repro.core import reconfig_runtime as lanes


def main():
    # --- Level 1: the paper's network -----------------------------------
    print("== ReSiPI photonic-interposer simulation (dedup trace) ==")
    tr = traffic.generate_trace("dedup", 60, jax.random.PRNGKey(0))
    out = simulate_all_archs(tr)
    for arch, s in out.items():
        print(f"  {arch:12s} latency {float(s['mean_latency']):7.2f} cyc   "
              f"power {float(s['mean_power_mw']):7.1f} mW   "
              f"energy {float(s['mean_energy']):9.1f}")
    resipi, prow = out["resipi"], out["prowaves"]
    print(f"  -> ReSiPI vs PROWAVES: "
          f"latency -{1 - float(resipi['mean_latency'])/float(prow['mean_latency']):.0%}, "
          f"power -{1 - float(resipi['mean_power_mw'])/float(prow['mean_power_mw']):.0%} "
          f"(paper: -37% / -25%)")

    # --- Level 2: the same controller on training traffic ----------------
    print("\n== Lane controller on synthetic collective traffic ==")
    cfg = lanes.LaneConfig(lane_bytes_per_step=1e6)
    st = lanes.LaneState.init(cfg)
    for phase, byte_rate in (("heavy", 3.5e6), ("light", 2e5),
                             ("medium", 1.2e6)):
        for _ in range(20):
            st = lanes.meter_step(st, jnp.float32(byte_rate))
        st, rec = lanes.epoch_update(st, cfg)
        print(f"  phase {phase:6s}: load {float(rec['load']):5.2f} -> "
              f"{int(rec['lanes_after'])} lanes")
    print("  (gateway-activation law Eqs. 5-7, applied to TPU comm lanes)")


if __name__ == "__main__":
    main()
