"""Fleet walkthrough: one launchable co-design DSE job, three ways.

    PYTHONPATH=src python examples/fleet_sweep.py

Runs the same small (chiplets x placements x workloads) grid through
`python -m repro.launch.fleet`:

  1. a fresh process with an empty persistent cache (cold compiles),
  2. the same job again in a new process sharing the cache (warm start —
     this is what a fleet worker joining mid-campaign experiences),
  3. one emulated-host shard (`--shard 0:2`): the contiguous grid rows a
     real 2-process fleet member would own, bit-identical to rows 0..k/2
     of the full run.

On a multi-host deployment the same job runs as one worker per host:

    python -m repro.launch.fleet --processes 8 --process-id $RANK \
        --coordinator head-node:12345 --cache-dir /shared/jax-cache
"""
import json
import os
import subprocess
import sys
import tempfile
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent

GRID = ["--chiplets", "4,9", "--placements", "2",
        "--workloads", "uniform,bursty", "--intervals", "8",
        "--reps", "2", "--seed", "0"]


def fleet(extra, out_path, cache_dir):
    env = dict(os.environ, PYTHONPATH=str(REPO / "src"))
    subprocess.run(
        [sys.executable, "-m", "repro.launch.fleet", *GRID, *extra,
         "--cache-dir", str(cache_dir), "--out", str(out_path)],
        cwd=REPO, env=env, check=True)
    with open(out_path) as f:
        return json.load(f)


def main():
    with tempfile.TemporaryDirectory() as tmp:
        tmp = Path(tmp)
        cache = tmp / "jax-cache"

        print("== 1. cold run (empty persistent cache) ==")
        cold = fleet([], tmp / "cold.json", cache)
        print(f"   {cold['grid_points']} grid points, first call "
              f"{cold['first_call_s']:.2f}s (compiles), then "
              f"{cold['points_per_sec']:.1f} points/s; best point "
              f"{cold['best_point']['label']}")

        print("== 2. warm run (new process, same cache) ==")
        warm = fleet([], tmp / "warm.json", cache)
        print(f"   first call {warm['first_call_s']:.2f}s — "
              f"{warm['first_call_s'] / cold['first_call_s']:.0%} of cold "
              f"({warm['cache']['entries']} cache entries, "
              f"{warm['cache']['bytes'] / 1e6:.1f} MB)")

        print("== 3. emulated-host shard 0 of 2 ==")
        shard = fleet(["--shard", "0:2"], tmp / "shard.json", cache)
        print(f"   {shard['grid_points']} of "
              f"{shard['grid_points_full']} points "
              f"({shard['sweep_wall_s']:.3f}s) — the same rows a real "
              f"2-process fleet member owns")


if __name__ == "__main__":
    main()
