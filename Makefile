# Developer entry points. PYTHONPATH=src is the repo's import convention
# (ROADMAP.md tier-1 verify line); the repo root rides along so the
# `benchmarks` namespace package resolves when a bench runs standalone.
PY := PYTHONPATH=src:.$(if $(PYTHONPATH),:$(PYTHONPATH)) python

.PHONY: verify test smoke bench bench-placement bench-search bench-pareto bench-traffic bench-faults bench-serve bench-kernels bench-distributed

# Pre-merge gate: tier-1 pytest + the padded-topology-sweep CPU smoke.
verify:
	$(PY) benchmarks/smoke.py

test:
	$(PY) -m pytest -x -q

# Just the ~5 s compiled padded-path smoke (no pytest).
smoke:
	$(PY) benchmarks/smoke.py --smoke-only

bench:
	$(PY) benchmarks/run.py

# Just the compiled placement-search benchmark (-> BENCH_placement.json).
bench-placement:
	$(PY) benchmarks/bench_placement.py

# Device-resident vs host-loop search engines (-> BENCH_search.json).
bench-search:
	$(PY) benchmarks/bench_search.py

# Just the one-dispatch Pareto co-design benchmark (topology x placement
# x knob joint search vs the sequential per-topology loop)
bench-pareto:
	$(PY) benchmarks/bench_pareto.py

# Just the workload-DSE / ragged-batch / streaming benchmark
# (-> BENCH_traffic.json).
bench-traffic:
	$(PY) benchmarks/bench_traffic.py

# Fault-injection + closed-loop self-healing (-> BENCH_faults.json).
bench-faults:
	$(PY) benchmarks/bench_faults.py

# Continuous-batching session server: nominal / overload / fault-storm
# phases (-> BENCH_serve.json).
bench-serve:
	$(PY) benchmarks/bench_serve.py

# Fused epoch_step Pallas body vs the XLA scan body
# (-> BENCH_kernels.json; interpret off-TPU, compiled on TPU).
bench-kernels:
	$(PY) benchmarks/bench_kernels.py

# Fleet: emulated-host scaling, real 2-process jax.distributed parity,
# AOT/persistent-cache cold-start removal (-> BENCH_distributed.json).
bench-distributed:
	$(PY) benchmarks/bench_distributed.py
