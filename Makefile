# Developer entry points. PYTHONPATH=src is the repo's import convention
# (ROADMAP.md tier-1 verify line).
PY := PYTHONPATH=src$(if $(PYTHONPATH),:$(PYTHONPATH)) python

.PHONY: verify test smoke bench

# Pre-merge gate: tier-1 pytest + the padded-topology-sweep CPU smoke.
verify:
	$(PY) benchmarks/smoke.py

test:
	$(PY) -m pytest -x -q

# Just the ~5 s compiled padded-path smoke (no pytest).
smoke:
	$(PY) benchmarks/smoke.py --smoke-only

bench:
	$(PY) benchmarks/run.py
